//! Deterministic fault injection: named failpoints with seeded schedules.
//!
//! The fault-tolerance layer (crash-safe checkpoints, shard-error policies,
//! poisoned-epoch recovery) is only trustworthy if its failure paths are
//! *exercised*, and real IO faults are rare and nondeterministic. This
//! module plants named **failpoints** at the spots where production faults
//! occur — shard opens/reads, `mmap(2)`, checkpoint writes, pool workers,
//! prefetch waves — and lets tests and operators arm them with seeded,
//! reproducible schedules:
//!
//! | schedule        | spec syntax              | behaviour                         |
//! |-----------------|--------------------------|-----------------------------------|
//! | fail once       | `shard.read=once`        | first hit fails, rest pass        |
//! | fail nth        | `shard.read=nth:3`       | 3rd hit fails (1-based)           |
//! | fail with prob  | `shard.read=prob:0.1:42` | each hit fails w.p. 0.1, seed 42  |
//! | inject latency  | `shard.read=latency:5ms` | every hit sleeps, never fails     |
//!
//! Multiple entries join with `;` (or `,`):
//! `A2PSGD_FAULTS="shard.read=prob:0.05:7;checkpoint.write=once"`. The same
//! grammar is accepted by the `[fault] points = "…"` TOML key and the
//! `--faults` CLI flag.
//!
//! # Dark-mode cost
//!
//! Exactly like the obs layer, the *disabled* path is the design target:
//! every [`should_fail`] call is a single `Relaxed` load of one global
//! `AtomicBool` that short-circuits before touching any per-point slot.
//! Compiling with the `fault-off` feature pins [`enabled`] to a constant
//! `false`, deleting even that load — the branch folds away entirely.
//!
//! # Determinism
//!
//! Probability schedules hash `(seed, hit-index)` through SplitMix64, so a
//! given spec produces the same fail/pass sequence on every run and every
//! platform — the fault-soak suite replays hundreds of seeded schedules and
//! asserts identical outcomes. Schedules are process-global (like metric
//! state); tests that arm them serialize on a mutex and [`reset`] after.

use crate::Result;
use anyhow::{anyhow, bail};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};

/// A named site where a fault can be injected (see the module table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailPoint {
    /// `shard.open` — opening a packed `.a2ps` shard for reading.
    ShardOpen,
    /// `shard.read` — decoding a chunk/range out of an open shard.
    ShardRead,
    /// `mmap.map` — the `mmap(2)` call itself (fires the owned fallback).
    MmapMap,
    /// `checkpoint.write` — mid-stream during an atomic checkpoint write
    /// (simulates a crash leaving a torn temp file).
    CheckpointWrite,
    /// `pool.worker` — a worker-pool job (fires as a worker panic).
    PoolWorker,
    /// `prefetch.wave` — the background decode of the next streaming wave.
    PrefetchWave,
    /// `dist.worker` — a distributed worker handling a coordinator stratum
    /// assignment (fires as a worker death: the connection drops and the
    /// coordinator continues degraded).
    DistWorker,
}

impl FailPoint {
    /// Every failpoint, for catalogs and `reset` sweeps.
    pub const ALL: [FailPoint; 7] = [
        FailPoint::ShardOpen,
        FailPoint::ShardRead,
        FailPoint::MmapMap,
        FailPoint::CheckpointWrite,
        FailPoint::PoolWorker,
        FailPoint::PrefetchWave,
        FailPoint::DistWorker,
    ];

    /// Stable spec/wire name (`shard.open`, `checkpoint.write`, …).
    pub const fn name(self) -> &'static str {
        match self {
            FailPoint::ShardOpen => "shard.open",
            FailPoint::ShardRead => "shard.read",
            FailPoint::MmapMap => "mmap.map",
            FailPoint::CheckpointWrite => "checkpoint.write",
            FailPoint::PoolWorker => "pool.worker",
            FailPoint::PrefetchWave => "prefetch.wave",
            FailPoint::DistWorker => "dist.worker",
        }
    }

    /// Inverse of [`FailPoint::name`].
    pub fn from_name(s: &str) -> Option<FailPoint> {
        FailPoint::ALL.iter().copied().find(|p| p.name() == s)
    }

    const fn idx(self) -> usize {
        match self {
            FailPoint::ShardOpen => 0,
            FailPoint::ShardRead => 1,
            FailPoint::MmapMap => 2,
            FailPoint::CheckpointWrite => 3,
            FailPoint::PoolWorker => 4,
            FailPoint::PrefetchWave => 5,
            FailPoint::DistWorker => 6,
        }
    }
}

/// A parsed failure schedule for one point (pure value — applying it to the
/// process-global slots happens in [`arm`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Disarmed.
    Off,
    /// Fail the first hit only.
    Once,
    /// Fail the `n`-th hit (1-based), pass all others.
    Nth(u64),
    /// Fail each hit independently with probability `p`, deterministically
    /// derived from `(seed, hit-index)`.
    Prob { p: f64, seed: u64 },
    /// Never fail; sleep this many microseconds on every hit.
    LatencyUs(u64),
}

impl Schedule {
    /// Would this schedule fire on hit index `n` (0-based)? Pure — the
    /// deterministic core of [`should_fail`], unit-testable without
    /// touching global state. Latency schedules never "fire".
    pub fn fires(self, n: u64) -> bool {
        match self {
            Schedule::Off | Schedule::LatencyUs(_) => false,
            Schedule::Once => n == 0,
            Schedule::Nth(k) => n + 1 == k,
            Schedule::Prob { p, seed } => {
                // Uniform in [0, 1) from the top 53 bits of a SplitMix64
                // hash of (seed, n) — platform-independent.
                let h = splitmix64(seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                u < p
            }
        }
    }
}

// Slot encoding: mode selects the Schedule variant, param/seed carry its
// payload (param holds f64 bits for Prob, count for Nth, µs for Latency).
const MODE_OFF: u8 = 0;
const MODE_ONCE: u8 = 1;
const MODE_NTH: u8 = 2;
const MODE_PROB: u8 = 3;
const MODE_LATENCY: u8 = 4;

struct Slot {
    mode: AtomicU8,
    param: AtomicU64,
    seed: AtomicU64,
    hits: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // const used only as an array initializer
const SLOT_INIT: Slot = Slot {
    mode: AtomicU8::new(MODE_OFF),
    param: AtomicU64::new(0),
    seed: AtomicU64::new(0),
    hits: AtomicU64::new(0),
};

static SLOTS: [Slot; 7] = [SLOT_INIT; 7];

/// The one word the dark path reads: false ⇒ no failpoint is armed and
/// [`should_fail`] returns before touching any slot.
static FAULTS_ENABLED: AtomicBool = AtomicBool::new(false);

/// Is any failpoint armed? Single `Relaxed` load; constant `false` (the
/// whole layer folds away) under the `fault-off` feature.
#[cfg(not(feature = "fault-off"))]
#[inline]
pub fn enabled() -> bool {
    FAULTS_ENABLED.load(Ordering::Relaxed)
}

/// `fault-off` build: the layer is compiled out.
#[cfg(feature = "fault-off")]
#[inline(always)]
pub fn enabled() -> bool {
    false
}

/// Record a hit at `p` and report whether the armed schedule says this hit
/// fails. The caller decides what "fail" means at its site (an `Err`, a
/// panic, a fallback path). Counts [`crate::obs::Ctr::FaultsInjected`] when
/// it fires.
#[inline]
pub fn should_fail(p: FailPoint) -> bool {
    if !enabled() {
        return false;
    }
    should_fail_slow(p)
}

#[cold]
fn should_fail_slow(p: FailPoint) -> bool {
    let slot = &SLOTS[p.idx()];
    let mode = slot.mode.load(Ordering::Relaxed);
    if mode == MODE_OFF {
        return false;
    }
    // Hit indices are allocated with a real RMW: concurrent hitters must
    // each see a distinct index or nth/once schedules misfire.
    let n = slot.hits.fetch_add(1, Ordering::Relaxed);
    let param = slot.param.load(Ordering::Relaxed);
    let sched = match mode {
        MODE_ONCE => Schedule::Once,
        MODE_NTH => Schedule::Nth(param),
        MODE_PROB => Schedule::Prob { p: f64::from_bits(param), seed: slot.seed.load(Ordering::Relaxed) },
        MODE_LATENCY => {
            std::thread::sleep(std::time::Duration::from_micros(param));
            return false;
        }
        _ => return false,
    };
    let fire = sched.fires(n);
    if fire {
        crate::obs::add(crate::obs::Ctr::FaultsInjected, 1);
    }
    fire
}

/// [`should_fail`] packaged as the error the IO sites return: `Some(err)`
/// when the hit fails, `None` to proceed.
#[inline]
pub fn fail_err(p: FailPoint) -> Option<anyhow::Error> {
    if should_fail(p) {
        Some(anyhow!("injected fault: {}", p.name()))
    } else {
        None
    }
}

/// Cumulative hit count at `p` since the last [`reset`] (armed periods
/// only — dark hits are not counted).
pub fn hits(p: FailPoint) -> u64 {
    SLOTS[p.idx()].hits.load(Ordering::Relaxed)
}

/// Parse a spec string (`point=mode[:arg[:seed]]`, entries joined by `;` or
/// `,`) into `(point, schedule)` pairs without touching global state.
pub fn parse_spec(spec: &str) -> Result<Vec<(FailPoint, Schedule)>> {
    let mut out = Vec::new();
    for entry in spec.split([';', ',']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, mode) = entry
            .split_once('=')
            .ok_or_else(|| anyhow!("fault spec entry `{entry}` is missing `=`"))?;
        let point = FailPoint::from_name(name.trim()).ok_or_else(|| {
            anyhow!(
                "unknown failpoint `{}` (known: {})",
                name.trim(),
                FailPoint::ALL.map(|p| p.name()).join(", ")
            )
        })?;
        out.push((point, parse_schedule(mode.trim())?));
    }
    Ok(out)
}

fn parse_schedule(mode: &str) -> Result<Schedule> {
    let mut parts = mode.split(':');
    let kind = parts.next().unwrap_or("");
    let arg = parts.next();
    let extra = parts.next();
    if parts.next().is_some() {
        bail!("fault schedule `{mode}` has too many `:` fields");
    }
    match kind {
        "off" => Ok(Schedule::Off),
        "once" => Ok(Schedule::Once),
        "nth" => {
            let n: u64 = arg
                .ok_or_else(|| anyhow!("`nth` needs a count, e.g. nth:3"))?
                .parse()
                .map_err(|_| anyhow!("bad nth count in `{mode}`"))?;
            if n == 0 {
                bail!("nth is 1-based; `nth:0` never fires");
            }
            Ok(Schedule::Nth(n))
        }
        "prob" => {
            let p: f64 = arg
                .ok_or_else(|| anyhow!("`prob` needs a probability, e.g. prob:0.1"))?
                .parse()
                .map_err(|_| anyhow!("bad probability in `{mode}`"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("probability {p} out of [0, 1] in `{mode}`");
            }
            let seed: u64 = match extra {
                Some(s) => s.parse().map_err(|_| anyhow!("bad seed in `{mode}`"))?,
                None => 0,
            };
            Ok(Schedule::Prob { p, seed })
        }
        "latency" => {
            let a = arg.ok_or_else(|| anyhow!("`latency` needs a duration, e.g. latency:5ms"))?;
            if extra.is_some() {
                bail!("latency takes no seed field in `{mode}`");
            }
            Ok(Schedule::LatencyUs(parse_duration_us(a)?))
        }
        _ => bail!("unknown fault schedule `{kind}` (off|once|nth|prob|latency)"),
    }
}

/// Duration with optional `us`/`ms`/`s` suffix; bare numbers are µs.
fn parse_duration_us(s: &str) -> Result<u64> {
    let (num, mul) = if let Some(n) = s.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000)
    } else {
        (s, 1)
    };
    let v: u64 = num.trim().parse().map_err(|_| anyhow!("bad duration `{s}`"))?;
    Ok(v.saturating_mul(mul))
}

/// Arm the process-global failpoints from a spec string. Entries replace
/// any previous schedule at their point; points not named keep theirs.
/// Hit counters for the named points restart at zero.
pub fn arm(spec: &str) -> Result<()> {
    for (point, sched) in parse_spec(spec)? {
        arm_point(point, sched);
    }
    Ok(())
}

/// Arm a single point with an already-parsed schedule.
pub fn arm_point(point: FailPoint, sched: Schedule) {
    let slot = &SLOTS[point.idx()];
    let (mode, param, seed) = match sched {
        Schedule::Off => (MODE_OFF, 0, 0),
        Schedule::Once => (MODE_ONCE, 0, 0),
        Schedule::Nth(n) => (MODE_NTH, n, 0),
        Schedule::Prob { p, seed } => (MODE_PROB, p.to_bits(), seed),
        Schedule::LatencyUs(us) => (MODE_LATENCY, us, 0),
    };
    slot.hits.store(0, Ordering::Relaxed);
    slot.param.store(param, Ordering::Relaxed);
    slot.seed.store(seed, Ordering::Relaxed);
    slot.mode.store(mode, Ordering::Relaxed);
    if mode != MODE_OFF {
        FAULTS_ENABLED.store(true, Ordering::Relaxed);
    } else if SLOTS.iter().all(|s| s.mode.load(Ordering::Relaxed) == MODE_OFF) {
        FAULTS_ENABLED.store(false, Ordering::Relaxed);
    }
}

/// Arm from the `A2PSGD_FAULTS` env var if set and non-empty. Returns
/// whether anything was armed.
pub fn arm_env() -> Result<bool> {
    match std::env::var("A2PSGD_FAULTS") {
        Ok(v) if !v.trim().is_empty() => {
            arm(&v)?;
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Disarm every point, zero every hit counter, and return to dark mode.
pub fn reset() {
    for slot in &SLOTS {
        slot.mode.store(MODE_OFF, Ordering::Relaxed);
        slot.param.store(0, Ordering::Relaxed);
        slot.seed.store(0, Ordering::Relaxed);
        slot.hits.store(0, Ordering::Relaxed);
    }
    FAULTS_ENABLED.store(false, Ordering::Relaxed);
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// Unit tests stay *pure* — they exercise the parser and the deterministic
// schedule math only. Tests that arm the process-global slots live in
// `tests/fault_soak.rs`, serialized on a mutex, because lib unit tests run
// concurrently and armed failpoints would leak into unrelated tests.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for p in FailPoint::ALL {
            assert_eq!(FailPoint::from_name(p.name()), Some(p));
        }
        assert_eq!(FailPoint::from_name("nope"), None);
    }

    #[test]
    fn spec_parses_every_schedule_kind() {
        let got = parse_spec(
            "shard.open=once; shard.read=nth:3, mmap.map=prob:0.25:9;\
             checkpoint.write=latency:5ms; pool.worker=off",
        )
        .unwrap();
        assert_eq!(
            got,
            vec![
                (FailPoint::ShardOpen, Schedule::Once),
                (FailPoint::ShardRead, Schedule::Nth(3)),
                (FailPoint::MmapMap, Schedule::Prob { p: 0.25, seed: 9 }),
                (FailPoint::CheckpointWrite, Schedule::LatencyUs(5_000)),
                (FailPoint::PoolWorker, Schedule::Off),
            ]
        );
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(parse_spec("shard.read").is_err(), "missing =");
        assert!(parse_spec("bogus.point=once").is_err(), "unknown point");
        assert!(parse_spec("shard.read=sometimes").is_err(), "unknown mode");
        assert!(parse_spec("shard.read=nth").is_err(), "nth without count");
        assert!(parse_spec("shard.read=nth:0").is_err(), "nth is 1-based");
        assert!(parse_spec("shard.read=prob:1.5").is_err(), "p out of range");
        assert!(parse_spec("shard.read=prob:0.5:7:9").is_err(), "extra field");
        assert!(parse_spec("shard.read=latency:5ms:3").is_err(), "latency seed");
    }

    #[test]
    fn durations_accept_suffixes() {
        assert_eq!(parse_duration_us("250").unwrap(), 250);
        assert_eq!(parse_duration_us("250us").unwrap(), 250);
        assert_eq!(parse_duration_us("5ms").unwrap(), 5_000);
        assert_eq!(parse_duration_us("2s").unwrap(), 2_000_000);
        assert!(parse_duration_us("fast").is_err());
    }

    #[test]
    fn once_and_nth_fire_exactly_once() {
        let once: Vec<bool> = (0..5).map(|n| Schedule::Once.fires(n)).collect();
        assert_eq!(once, vec![true, false, false, false, false]);
        let nth: Vec<bool> = (0..5).map(|n| Schedule::Nth(3).fires(n)).collect();
        assert_eq!(nth, vec![false, false, true, false, false]);
    }

    #[test]
    fn prob_schedule_is_deterministic_and_seed_sensitive() {
        let s1 = Schedule::Prob { p: 0.3, seed: 1 };
        let a: Vec<bool> = (0..256).map(|n| s1.fires(n)).collect();
        let b: Vec<bool> = (0..256).map(|n| s1.fires(n)).collect();
        assert_eq!(a, b, "same seed ⇒ same sequence");
        let s2 = Schedule::Prob { p: 0.3, seed: 2 };
        let c: Vec<bool> = (0..256).map(|n| s2.fires(n)).collect();
        assert_ne!(a, c, "different seed ⇒ different sequence");
        let fired = a.iter().filter(|&&f| f).count();
        assert!((20..135).contains(&fired), "p=0.3 over 256 hits fired {fired} times");
    }

    #[test]
    fn prob_extremes_never_and_always_fire() {
        let never = Schedule::Prob { p: 0.0, seed: 7 };
        assert!((0..128).all(|n| !never.fires(n)));
        let always = Schedule::Prob { p: 1.0, seed: 7 };
        assert!((0..128).all(|n| always.fires(n)));
    }

    #[test]
    fn off_and_latency_never_fire() {
        assert!((0..16).all(|n| !Schedule::Off.fires(n)));
        assert!((0..16).all(|n| !Schedule::LatencyUs(1).fires(n)));
    }
}

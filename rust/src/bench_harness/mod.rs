//! Benchmark harness (no `criterion` offline): warmup + timed iterations,
//! robust statistics, paper-style table printing, and CSV emission for the
//! figure-regenerating benches.

use crate::metrics::MeanStd;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean ± std of per-iteration seconds.
    pub fn stats(&self) -> MeanStd {
        MeanStd::from(&self.samples)
    }

    /// Median per-iteration seconds. NaN-safe: `total_cmp` orders NaNs to
    /// the end instead of panicking (the repo's `take_top_k` idiom), so a
    /// poisoned sample can't take down a whole bench run.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        let m = self.median();
        if m > 0.0 {
            1.0 / m
        } else {
            f64::INFINITY
        }
    }

    /// One human-readable line.
    pub fn summary(&self) -> String {
        let s = self.stats();
        format!(
            "{:<44} {:>12} median {:>12} ±{:>10}  ({} iters)",
            self.name,
            fmt_secs(self.median()),
            fmt_secs(s.mean),
            fmt_secs(s.std),
            self.samples.len()
        )
    }
}

/// Human-scale seconds formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples }
}

/// Run a batched micro-benchmark: `f` executes `batch` operations per call;
/// reported samples are per-*operation* seconds.
pub fn bench_batched(
    name: &str,
    warmup: usize,
    iters: usize,
    batch: u64,
    mut f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, warmup, iters, &mut f);
    for s in &mut r.samples {
        *s /= batch as f64;
    }
    r
}

/// Aligned-table printer for bench output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Write a CSV file under `results/`, creating the directory. Atomic so a
/// crash mid-write never leaves a torn artifact behind.
pub fn write_results_csv(filename: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(filename);
    crate::data::atomic_file::write_atomic(&path, contents.as_bytes())
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, format!("{e:#}")))?;
    Ok(path)
}

/// Minimal JSON emission (no `serde` offline) for machine-readable bench
/// artifacts like `BENCH_hotpath.json`. Only what the bench pipeline needs:
/// objects, arrays of pre-serialized values, strings, and finite numbers
/// (non-finite floats become `null` — NaN is not valid JSON).
pub mod json {
    /// Escape a string for a JSON string literal (without quotes).
    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    /// Serialize a float (non-finite → `null`).
    pub fn num(v: f64) -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        }
    }

    /// Serialize an array of pre-serialized JSON values.
    pub fn array<I: IntoIterator<Item = String>>(items: I) -> String {
        let mut out = String::from("[");
        for (k, item) in items.into_iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(&item);
        }
        out.push(']');
        out
    }

    /// Incremental JSON object builder.
    #[derive(Default)]
    pub struct Obj {
        buf: String,
    }

    impl Obj {
        /// Empty object.
        pub fn new() -> Self {
            Obj { buf: String::new() }
        }

        fn key(&mut self, k: &str) -> &mut Self {
            if !self.buf.is_empty() {
                self.buf.push(',');
            }
            self.buf.push('"');
            self.buf.push_str(&escape(k));
            self.buf.push_str("\":");
            self
        }

        /// String field.
        pub fn str(mut self, k: &str, v: &str) -> Self {
            self.key(k).buf.push_str(&format!("\"{}\"", escape(v)));
            self
        }

        /// Float field (non-finite → `null`).
        pub fn num(mut self, k: &str, v: f64) -> Self {
            self.key(k).buf.push_str(&num(v));
            self
        }

        /// Integer field.
        pub fn int(mut self, k: &str, v: u64) -> Self {
            self.key(k).buf.push_str(&v.to_string());
            self
        }

        /// Pre-serialized JSON value field (nested object/array).
        pub fn raw(mut self, k: &str, v: &str) -> Self {
            self.key(k).buf.push_str(v);
            self
        }

        /// Finish into a JSON object string.
        pub fn build(self) -> String {
            format!("{{{}}}", self.buf)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 10);
        assert!(r.median() >= 0.0);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn bench_batched_divides() {
        let r = bench_batched("sleepy", 0, 3, 1000, || {
            std::thread::sleep(std::time::Duration::from_millis(1))
        });
        // ≈1ms per call / 1000 ops ⇒ ≈1µs per op.
        assert!(r.median() < 1e-4, "median={}", r.median());
    }

    #[test]
    fn median_even_odd() {
        let r = BenchResult { name: "x".into(), samples: vec![3.0, 1.0, 2.0] };
        assert_eq!(r.median(), 2.0);
        let r2 = BenchResult { name: "x".into(), samples: vec![4.0, 1.0, 2.0, 3.0] };
        assert_eq!(r2.median(), 2.5);
    }

    /// Regression: `partial_cmp().unwrap()` panicked on NaN samples.
    #[test]
    fn median_is_nan_safe() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![3.0, f64::NAN, 1.0],
        };
        // total_cmp sorts NaN last: [1.0, 3.0, NaN] → median 3.0, no panic.
        assert_eq!(r.median(), 3.0);
        let all_nan = BenchResult { name: "y".into(), samples: vec![f64::NAN] };
        assert!(all_nan.median().is_nan());
    }

    #[test]
    fn json_escapes_and_builds() {
        let obj = json::Obj::new()
            .str("name", "a \"b\"\n")
            .num("x", 1.5)
            .num("bad", f64::NAN)
            .int("n", 7)
            .raw("arr", &json::array(["1".to_string(), "2".to_string()]));
        assert_eq!(
            obj.build(),
            r#"{"name":"a \"b\"\n","x":1.5,"bad":null,"n":7,"arr":[1,2]}"#
        );
        assert_eq!(json::array(Vec::<String>::new()), "[]");
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "123".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().filter(|&c| c == '-').count(), lines[1].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn summary_contains_name_and_iters() {
        let r = bench("mybench", 0, 5, || {});
        let s = r.summary();
        assert!(s.contains("mybench") && s.contains("5 iters"));
    }
}

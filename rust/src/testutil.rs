//! Shared helpers for tests that must also run under Miri and TSan.
//!
//! Miri interprets MIR roughly three orders of magnitude slower than a
//! native build, so the concurrency tests scale their iteration counts down
//! when interpreted. Detection is twofold: `cfg!(miri)` for real Miri runs,
//! plus the `A2PSGD_MIRI=1` environment variable so the shortened schedules
//! can be exercised (and debugged) on a native build too — CI's Miri lane
//! sets both. The stress harness (`tests/stress_interleave.rs`) layers
//! `A2PSGD_STRESS_ITERS` on top for soak runs.

/// True when running under Miri or with `A2PSGD_MIRI=1` set.
pub fn miri_mode() -> bool {
    cfg!(miri) || std::env::var("A2PSGD_MIRI").map(|v| v == "1").unwrap_or(false)
}

/// Pick an iteration budget: `full` natively, `short` under Miri (or the
/// `A2PSGD_MIRI=1` rehearsal mode).
pub fn budget(full: usize, short: usize) -> usize {
    if miri_mode() {
        short
    } else {
        full
    }
}

/// Stress-loop iteration count: an explicit `A2PSGD_STRESS_ITERS` wins,
/// then the Miri `short` cap, then the native default.
pub fn stress_iters(full: usize, short: usize) -> usize {
    if let Ok(v) = std::env::var("A2PSGD_STRESS_ITERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    budget(full, short)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_respects_mode() {
        if miri_mode() {
            assert_eq!(budget(10_000, 50), 50);
        } else {
            assert_eq!(budget(10_000, 50), 10_000);
        }
    }

    #[test]
    fn stress_iters_falls_back_to_budget() {
        // Not setting the env var here (process-global); just pin the
        // fallback path equivalence.
        if std::env::var("A2PSGD_STRESS_ITERS").is_err() {
            assert_eq!(stress_iters(123, 7), budget(123, 7));
        }
    }
}

//! Hand-rolled CLI (no `clap` offline): subcommands + `--flag value` pairs.
//!
//! ```text
//! a2psgd train   [--engine E] [--dataset D] [--threads N] [--epochs N]
//!                [--seed S] [--d D] [--eta F] [--lam F] [--gamma F]
//!                [--partition uniform|balanced] [--kernel auto|scalar]
//!                [--memory auto|resident|streaming] [--stream-mb N]
//!                [--config FILE]
//!                [--data-file PATH] [--out DIR] [--no-early-stop]
//! a2psgd compare [--dataset D] [--threads N] [--seeds N] [--epochs N] [--out DIR]
//! a2psgd serve   [--dataset D] [--requests N] [--artifacts DIR]
//!                [--listen ADDR] [--serve-secs N] [--quant int8|f16|f32]
//!                [--deadline-ms N] [--queue-cap N] [--native]
//! a2psgd stream  [--dataset D] [--warm-frac F] [--batch N] [--window N]
//!                [--publish-every N] [--foldin-steps N] [--threads N]
//!                [--epochs N] [--config FILE] [--save PATH] [--native]
//! a2psgd bench   [--dataset D] [--iters N] [--warmup N] [--threads N]
//!                [--d D] [--seed S] [--config FILE] [--out FILE]
//! a2psgd pack    (--data-file PATH | --dataset D) --out DIR
//!                [--shard-mb N] [--seed S] [--config FILE]
//! a2psgd dist-train  --dataset SHARD_DIR --workers N [--col-blocks C]
//!                    [--listen ADDR] [--exchange-dir DIR] [--epochs N]
//!                    [--threads N] [--seed S] [--d D] [--config FILE]
//! a2psgd dist-worker --connect ADDR --worker-id I --dataset SHARD_DIR
//!                    [--threads N]
//! a2psgd trace-export --input TRACE.jsonl --out TRACE.json
//! a2psgd gen-data --dataset D --out FILE [--seed S]
//! a2psgd print-config [--dataset D]
//! a2psgd eval    --data-file PATH (reserved)
//! ```

use crate::Result;
use anyhow::{bail, Context};
use std::collections::HashMap;

/// A parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional token.
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take no value.
const SWITCHES: &[&str] = &["no-early-stop", "verbose", "help", "xla-eval", "native"];

impl Args {
    /// Parse a raw argv (excluding the binary name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                args.command = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument {tok:?}");
            };
            if SWITCHES.contains(&name) {
                args.switches.push(name.to_string());
                continue;
            }
            let value = it
                .next()
                .with_context(|| format!("flag --{name} expects a value"))?;
            args.flags.insert(name.to_string(), value.clone());
        }
        Ok(args)
    }

    /// String flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed flag.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {s:?}: {e}")),
        }
    }

    /// Boolean switch present?
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Flags the caller never read (typo detection).
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.flags
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "a2psgd — Accelerated Asynchronous Parallel SGD for HDS low-rank representation

USAGE:
  a2psgd train        train one engine on one dataset, print the report
  a2psgd compare      run the paper's engine set, print Tables III/IV rows
  a2psgd serve        train then serve predictions and quantized top-k
                      (XLA/PJRT or native); --listen adds a TCP front end
                      with per-request deadlines and admission control
  a2psgd stream       warm-train, then stream live events: fold-in, online
                      NAG updates, and zero-downtime factor hot-swap
  a2psgd bench        hot-path benchmark pipeline: update-kernel micro,
                      scalar-vs-SIMD kernel A/B across ranks, text-vs-shard
                      ingest A/B, mmap-vs-BufReader shard readback micro,
                      resident-vs-streaming epoch A/B, layout A/B (COO vs
                      block-CSR sweep), per-engine epoch macro, scheduler
                      fairness, the pool-vs-scope epoch overhead micro, and
                      the serving tier (top-k p50/p99 under concurrent
                      clients + hot-swap churn, quantized recall@k) —
                      emits BENCH_hotpath.json at the repo root (--out
                      overrides)
  a2psgd pack         convert a ratings file (or builtin dataset) into a
                      packed .a2ps shard directory: versioned binary shards
                      split by row range, embedded id map, CRC per shard —
                      shard directories then train out-of-core (block
                      engines) or materialize for the others
  a2psgd dist-train   distributed shard-parallel training: a coordinator
                      assigning nnz-balanced shard row ranges to N worker
                      processes with DSGD column-block rotation — no two
                      workers ever write the same column factors — merging
                      factors at epoch barriers through the snapshot store
                      (see DISTRIBUTED.md)
  a2psgd dist-worker  one distributed worker process (normally spawned by
                      dist-train; run by hand for multi-host setups)
  a2psgd trace-export convert a span JSONL trace (from --trace) into a
                      chrome://tracing / Perfetto trace_event JSON file
  a2psgd gen-data     write a synthetic dataset to a ratings file
  a2psgd print-config print the paper's hyperparameter tables (I/II)
  a2psgd help         this text

COMMON FLAGS:
  --dataset small|medium|ml1m|epinions|<path>   (default: small)
                   a <path> may be a ratings text file or a packed .a2ps
                   shard directory; shard dirs train out-of-core on the
                   block engines (fpsgd, a2psgd) and materialize otherwise
  --format auto|text|shards   assert how `train` interprets the dataset
                   path (mismatch is an error; other commands auto-detect)
  --memory auto|resident|streaming   grid residency for shard-dir training:
                   resident decodes the whole block grid up front; streaming
                   re-decodes mmap-backed row-range tiles per epoch (bounded
                   by --stream-mb); auto picks streaming once the estimated
                   grid exceeds the budget (A2PSGD_MEMORY=... overrides auto)
  --stream-mb N    streaming tile budget in MiB (default: 512, or
                   `[data] stream_mb` from --config)
  --engine  seq|hogwild|dsgd|asgd|fpsgd|a2psgd|xla
  --threads N      worker threads (default: hardware, capped 32)
  --epochs N       max epochs
  --seeds N        seeds for `compare` (default: 3)
  --seed S         base RNG seed
  --d D            feature dimension (default: 16)
  --eta/--lam/--gamma F   hyperparameter overrides
  --partition uniform|balanced
  --kernel auto|scalar    update-kernel dispatch (auto = best SIMD path for
                          this CPU; scalar = reference path; the env var
                          A2PSGD_KERNEL=scalar forces scalar everywhere)
  --config FILE    TOML run config (flags override it)
  --out DIR        results directory (default: results/)
  --artifacts DIR  AOT artifacts (default: artifacts/)
  --no-early-stop  run all epochs

FAULT-TOLERANCE FLAGS (train):
  --checkpoint-every N   write a crash-safe checkpoint (atomic tmp + fsync +
                         rename, previous kept as <path>.prev) every N
                         epochs to --checkpoint PATH (default PATH:
                         <out>/checkpoint.a2pf)
  --checkpoint PATH      checkpoint file for --checkpoint-every
  --resume PATH          continue a run from a checkpoint (torn primaries
                         fall back to <path>.prev); block engines resume
                         bit-identically at --threads 1
  --on-shard-error fail|skip|retry   policy when a shard stays unreadable
                         mid-run (out-of-core path): fail aborts (default),
                         skip quarantines the shard and trains on the
                         survivors (degraded coverage is reported), retry
                         spends a deeper retry budget then fails
  --epoch-retries N      worker-panic containment: retry a poisoned epoch
                         from its boundary snapshot up to N times (2)
  --faults SPEC          arm deterministic fault injection, e.g.
                         \"shard.read=nth:3;checkpoint.write=once\" — see
                         A2PSGD_FAULTS / `[fault]` in --config

OBSERVABILITY FLAGS (train / stream / serve / bench):
  --metrics-json PATH  enable hot-path metrics and write a JSON snapshot
                       (counters, gauges, log2-bucketed latency histograms
                       with p50/p99) at the end of the run; `stream` also
                       rewrites it periodically while events flow
  --trace PATH         enable span tracing and write one JSON object per
                       span (JSONL) at the end of the run; convert with
                       `a2psgd trace-export` for chrome://tracing
                       (`[obs]` in --config sets the same switches)

BENCH FLAGS:
  --iters N          measured iterations / macro epochs (default: 3)
  --warmup N         unmeasured warmup iterations (default: 1)
  --out FILE         JSON artifact path (default: <repo root>/BENCH_hotpath.json)

PACK FLAGS:
  --data-file PATH   input ratings text file (or --dataset for a builtin)
  --out DIR          shard directory to create (required)
  --shard-mb N       target shard payload size in MiB (default: 64, or
                     `[data] shard_mb` from --config)

DIST FLAGS (dist-train / dist-worker):
  --workers N        worker processes to spawn and wait for (dist-train;
                     default: 2, or `[dist] workers` from --config; must
                     be ≤ the shard count — row ranges are shard-aligned)
  --col-blocks C     strata per epoch (default: workers; more blocks =
                     finer rotation granularity, same total work)
  --listen ADDR      coordinator control address (default: 127.0.0.1:0)
  --exchange-dir DIR factor checkpoint exchange directory (default:
                     <out>/dist-exchange; must be shared with workers)
  --connect ADDR     (dist-worker) coordinator address to register with
  --worker-id I      (dist-worker) this worker's index in 0..workers

TRACE-EXPORT FLAGS:
  --input PATH       span JSONL written by --trace (required)
  --out PATH         chrome trace_event JSON to write (required)

SERVE FLAGS:
  --listen ADDR      expose the service over a line-protocol TCP front end
                     (e.g. 127.0.0.1:7878; see SERVING.md for the grammar);
                     without it, `serve` answers --requests sampled queries
                     in process and exits
  --serve-secs N     with --listen: stop after N seconds (default: run
                     until killed; `[serve] serve_secs` from --config)
  --quant int8|f16|f32   top-k scan precision (default: int8 — quantized
                     per-item index rebuilt on each snapshot publish;
                     f32 = exact scan, no index)
  --deadline-ms N    default per-request TOPK deadline; requests that
                     cannot be answered in time get OVERLOADED (default:
                     0 = no deadline; a TOPK line's own deadline_ms wins)
  --queue-cap N      admission bound on the request queue (default: 1024);
                     beyond it deadline-carrying requests shed immediately

STREAM FLAGS:
  --warm-frac F      fraction of users trained offline, rest streamed (0.8);
                     for a shard-dir dataset the warm phase trains straight
                     off the matching shard prefix out of core (--memory
                     applies) and the cold shards replay as live events —
                     the dataset is never materialized end to end
  --batch N          max events per micro-batch
  --window N         sliding-window capacity
  --publish-every N  snapshot publish cadence (batches)
  --foldin-steps N   one-sided NAG sweeps per new node
  --save PATH        write checkpoint (v2, with meta) + .idmap at the end
  --native           serve with the native backend (no XLA artifacts)
"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_subcommand_and_flags() {
        let a = Args::parse(&sv(&["train", "--engine", "a2psgd", "--threads", "8"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("engine"), Some("a2psgd"));
        assert_eq!(a.get_parsed::<usize>("threads").unwrap(), Some(8));
    }

    #[test]
    fn switches_take_no_value() {
        let a = Args::parse(&sv(&["train", "--no-early-stop", "--epochs", "5"])).unwrap();
        assert!(a.has("no-early-stop"));
        assert_eq!(a.get_parsed::<u32>("epochs").unwrap(), Some(5));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["train", "--engine"])).is_err());
    }

    #[test]
    fn positional_after_command_errors() {
        assert!(Args::parse(&sv(&["train", "oops"])).is_err());
    }

    #[test]
    fn typed_parse_errors_are_nice() {
        let a = Args::parse(&sv(&["train", "--threads", "many"])).unwrap();
        let e = a.get_parsed::<usize>("threads").unwrap_err().to_string();
        assert!(e.contains("--threads"), "{e}");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = Args::parse(&sv(&["train", "--engin", "x"])).unwrap();
        assert_eq!(a.unknown_flags(&["engine"]), vec!["engin".to_string()]);
    }

    #[test]
    fn empty_argv_is_helpish() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.command, "");
    }
}

//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! [`Rng`] is xoshiro256** seeded via SplitMix64 — fast, high quality, and
//! reproducible across runs/platforms, which the experiment harness relies on
//! (every table in EXPERIMENTS.md is seed-pinned). Includes uniform ints,
//! floats, Gaussian (Box–Muller, cached), Fisher–Yates shuffle.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. one per worker thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift, debiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard Gaussian via Box–Muller (second sample cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian with mean/std as f32.
    #[inline]
    pub fn gauss_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gauss() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(42);
        let mut f1 = root.fork(0);
        let mut f2 = root.fork(1);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn gen_range_within_bounds() {
        let mut r = Rng::new(3);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_hits_all_small_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.gen_range(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn f32_range_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            let x = r.f32_range(-2.5, 3.5);
            assert!((-2.5..3.5).contains(&x));
        }
    }
}

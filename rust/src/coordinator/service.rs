//! Post-training prediction service: a request router + dynamic batcher in
//! front of the AOT `predict` artifact (vLLM-router-shaped, scaled to this
//! paper's serving story).
//!
//! Requests `(u, v)` arrive on a channel; the batcher drains up to the
//! artifact batch size B or until `max_wait` elapses, gathers factor rows,
//! executes one PJRT call, clamps to the rating scale, and answers each
//! request through its reply channel. Python is never involved.

use crate::model::Factors;
use crate::runtime::XlaRuntime;
use crate::Result;
use anyhow::Context;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One service request.
enum Request {
    /// Point prediction r̂(u, v).
    Predict { u: u32, v: u32, reply: mpsc::Sender<f32> },
    /// Top-k recommendation for user u (via the `recommend` artifact).
    TopK { u: u32, k: usize, reply: mpsc::Sender<Vec<(u32, f32)>> },
}

/// Service statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Requests answered.
    pub served: u64,
    /// PJRT batches executed.
    pub batches: u64,
    /// Top-k requests answered.
    pub topk_served: u64,
    /// Sum of batch occupancies (served / batches = mean batch size).
    pub occupancy_sum: u64,
}

impl ServiceStats {
    /// Mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Handle for submitting requests; cloneable across client threads.
#[derive(Clone)]
pub struct ServiceClient {
    tx: mpsc::Sender<Request>,
}

impl ServiceClient {
    /// Blocking point prediction.
    pub fn predict(&self, u: u32, v: u32) -> Result<f32> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Predict { u, v, reply })
            .ok()
            .context("service stopped")?;
        rx.recv().context("service dropped the request")
    }

    /// Blocking top-k recommendation (items the user rated in training are
    /// excluded when the service was built with a training matrix).
    pub fn top_k(&self, u: u32, k: usize) -> Result<Vec<(u32, f32)>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::TopK { u, k, reply })
            .ok()
            .context("service stopped")?;
        rx.recv().context("service dropped the request")
    }

    /// Submit many and wait for all (amortizes channel overhead in tests).
    pub fn predict_many(&self, pairs: &[(u32, u32)]) -> Result<Vec<f32>> {
        let mut rxs = Vec::with_capacity(pairs.len());
        for &(u, v) in pairs {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Request::Predict { u, v, reply })
                .ok()
                .context("service stopped")?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().context("service dropped a request"))
            .collect()
    }
}

/// The running service; shutting down requires all external
/// [`ServiceClient`] clones to be dropped first (their senders keep the
/// worker's receive loop alive).
pub struct PredictionService {
    client: ServiceClient,
    worker: std::thread::JoinHandle<ServiceStats>,
}

impl PredictionService {
    /// Spawn the batcher thread over trained factors.
    ///
    /// The PJRT runtime is constructed *inside* the worker thread (the xla
    /// crate's client is `!Send`), so this takes the artifacts directory and
    /// reports load/compile errors synchronously through a startup channel.
    ///
    /// `max_wait` bounds added latency when traffic is sparse: a non-full
    /// batch launches once the oldest queued request has waited this long.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        factors: Factors,
        clamp: (f32, f32),
        max_wait: Duration,
    ) -> Result<Self> {
        Self::start_with_exclusions(artifacts_dir, factors, clamp, max_wait, None)
    }

    /// [`PredictionService::start`] plus a training matrix whose items are
    /// excluded from each user's top-k candidates (standard protocol).
    pub fn start_with_exclusions(
        artifacts_dir: std::path::PathBuf,
        factors: Factors,
        clamp: (f32, f32),
        max_wait: Duration,
        train: Option<crate::sparse::CooMatrix>,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::spawn(move || {
            let runtime = match XlaRuntime::load(&artifacts_dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return ServiceStats::default();
                }
            };
            run_batcher(runtime, factors, clamp, max_wait, train, rx)
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(PredictionService { client: ServiceClient { tx }, worker }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                anyhow::bail!("service worker died during startup")
            }
        }
    }

    /// A client handle.
    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    /// Stop and collect stats (consumes the service). All other client
    /// clones must already be dropped, or this blocks until they are.
    pub fn shutdown(self) -> ServiceStats {
        let PredictionService { client, worker } = self;
        drop(client); // close our sender so the worker's recv errors out
        worker.join().expect("service worker panicked")
    }
}

fn run_batcher(
    runtime: XlaRuntime,
    factors: Factors,
    clamp: (f32, f32),
    max_wait: Duration,
    train: Option<crate::sparse::CooMatrix>,
    rx: mpsc::Receiver<Request>,
) -> ServiceStats {
    let b = runtime.shapes.b;
    let d = runtime.shapes.d;
    let mut stats = ServiceStats::default();
    let mut mu = vec![0f32; b * d];
    let mut nv = vec![0f32; b * d];
    // Top-k state: padded item matrix + per-user exclusion sets.
    let n_padded = crate::runtime::pad_item_matrix(&factors, runtime.shapes.v);
    let mut seen: Vec<std::collections::HashSet<u32>> =
        vec![std::collections::HashSet::new(); factors.nrows() as usize];
    if let Some(train) = &train {
        for e in train.entries() {
            seen[e.u as usize].insert(e.v);
        }
    }
    let empty = std::collections::HashSet::new();
    let mut batch: Vec<(u32, u32, mpsc::Sender<f32>)> = Vec::with_capacity(b);
    loop {
        // Block for the first request; then drain greedily until B or timeout.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => break, // all clients dropped
        };
        let mut pending = Some(first);
        let deadline = Instant::now() + max_wait;
        loop {
            match pending.take() {
                Some(Request::Predict { u, v, reply }) => batch.push((u, v, reply)),
                Some(Request::TopK { u, k, reply }) => {
                    // Top-k is a whole-catalog scan — served immediately,
                    // not batched with point predictions.
                    let ex = seen.get(u as usize).unwrap_or(&empty);
                    match runtime.top_k(&factors, &n_padded, u, k, ex) {
                        Ok(top) => {
                            let _ = reply.send(top);
                            stats.topk_served += 1;
                        }
                        Err(_) => return stats,
                    }
                }
                None => {}
            }
            if batch.len() >= b {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => pending = Some(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if batch.is_empty() {
            continue; // the window held only top-k traffic
        }
        // Gather rows; unused lanes keep zeros (prediction discarded).
        for (lane, (u, v, _)) in batch.iter().enumerate() {
            mu[lane * d..(lane + 1) * d].copy_from_slice(factors.m_row(*u));
            nv[lane * d..(lane + 1) * d].copy_from_slice(factors.n_row(*v));
        }
        for lane in batch.len()..b {
            mu[lane * d..(lane + 1) * d].iter_mut().for_each(|x| *x = 0.0);
            nv[lane * d..(lane + 1) * d].iter_mut().for_each(|x| *x = 0.0);
        }
        let preds = match runtime.predict_batch(&mu, &nv) {
            Ok(p) => p,
            Err(_) => break, // runtime failure: drop in-flight, stop service
        };
        stats.batches += 1;
        stats.occupancy_sum += batch.len() as u64;
        for (lane, (_, _, reply)) in batch.drain(..).enumerate() {
            let p = preds[lane].clamp(clamp.0, clamp.1);
            let _ = reply.send(p); // client may have gone away; fine
            stats.served += 1;
        }
    }
    stats
}

// Integration coverage (requires artifacts): rust/tests/integration_service.rs

//! Post-training prediction service: a request router + dynamic batcher in
//! front of a pluggable execution backend (vLLM-router-shaped, scaled to
//! this paper's serving story).
//!
//! Requests `(u, v)` arrive on a channel; the batcher drains up to the
//! backend batch size B or until `max_wait` elapses, gathers factor rows,
//! executes one backend call, clamps to the rating scale, and answers each
//! request through its reply channel. Python is never involved.
//!
//! # Factors are read through a snapshot store (zero-downtime hot swap)
//!
//! The batcher does not own the factor matrices. It pins the current
//! [`FactorSnapshot`] from a [`SnapshotStore`] **once per batch** and
//! gathers rows from that immutable pin, so a publisher (e.g. the online
//! trainer in [`crate::stream`]) can swap in refreshed — even *larger*,
//! after fold-in — factors at any time without the service restarting or a
//! request ever observing a torn write. [`ServiceStats::last_version`] and
//! [`ServiceStats::versions_seen`] record the handover history. Requests
//! naming nodes unknown to the pinned snapshot answer the rating-scale
//! midpoint (the calibrated "know nothing" prior) rather than failing.
//!
//! # Backends
//!
//! - **XLA/PJRT** — the AOT `predict`/`recommend` artifacts (requires the
//!   `xla` cargo feature and `make artifacts`).
//! - **Native** — a portable fallback computing the same dot products on
//!   the batcher thread through the dispatched SIMD kernel entry point
//!   (`model::dot` → `optim::kernel::dot`); used when artifacts are
//!   unavailable ([`BackendMode::Auto`]) or by explicit request
//!   ([`BackendMode::NativeOnly`]), which keeps the full online-serving
//!   pipeline runnable on any build.
//!
//! Bulk clients should prefer [`ServiceClient::predict_many`]: it enqueues
//! the whole pair list as a single request, so the batcher fills backend
//! batches in one drain instead of N channel round-trips.
//!
//! # Latency budget: deadlines, admission control, quantized top-k
//!
//! The request queue is **bounded** ([`ServiceOptions::queue_cap`]):
//! blocking submissions ([`ServiceClient::predict`], [`ServiceClient::
//! top_k`]) exert backpressure instead of queueing unboundedly, and the
//! deadline-aware path ([`ServiceClient::top_k_within`]) *sheds* — a full
//! queue answers [`TopKAnswer::Overloaded`] immediately rather than letting
//! the queue (and therefore every request's latency) grow without limit. A
//! request carrying a deadline that has already passed when the batcher
//! dequeues it is also answered `Overloaded` without paying for the scan.
//! Shed and miss volumes are visible in [`ServiceStats`] and the
//! `serve_shed` / `serve_deadline_miss` obs counters.
//!
//! Full-catalog top-k can scan a **quantized item index**
//! ([`crate::model::quant::QuantizedIndex`], [`ServiceOptions::quant`]):
//! int8-with-per-item-scale or f16 codes rebuilt once per published
//! snapshot version and scanned through the dispatched SIMD kernels —
//! scores match the f32 scan within the index's documented
//! [`error bound`](crate::model::quant::QuantizedIndex::error_bound).

use crate::model::quant::{QuantMode, QuantizedIndex};
use crate::model::snapshot::{FactorSnapshot, SnapshotStore};
use crate::model::Factors;
use crate::runtime::XlaRuntime;
use crate::Result;
use anyhow::Context;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch capacity of the native (non-XLA) backend.
const NATIVE_BATCH: usize = 64;

/// Default bound of the request queue (see [`ServiceOptions::queue_cap`]).
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// One service request.
enum Request {
    /// Point prediction r̂(u, v).
    Predict { u: u32, v: u32, reply: mpsc::Sender<f32> },
    /// Many point predictions submitted as one enqueued unit: the batcher
    /// fills backend batches directly from the pair list (one channel
    /// round-trip total) instead of draining N individual requests.
    PredictBatch { pairs: Vec<(u32, u32)>, reply: mpsc::Sender<Vec<f32>> },
    /// Top-k recommendation for user u; `deadline` (absolute) makes the
    /// batcher shed the request instead of serving it late.
    TopK { u: u32, k: usize, deadline: Option<Instant>, reply: mpsc::Sender<TopKAnswer> },
}

/// Answer to a top-k request under admission control.
#[derive(Clone, Debug, PartialEq)]
pub enum TopKAnswer {
    /// Ranked `(item, score)` pairs, best first (empty for unknown users).
    Ranked(Vec<(u32, f32)>),
    /// The request was shed: either the bounded queue was full at admission
    /// or the per-request deadline had already passed at dequeue. The
    /// explicit answer replaces unbounded queueing — retry with backoff or
    /// degrade gracefully; see SERVING.md's runbook.
    Overloaded,
}

/// Shared, growable per-user top-k exclusion sets.
///
/// Seeded from the training matrix at service start and (optionally) shared
/// with the online trainer, which records streamed interactions — so a user
/// is never recommended an item they already consumed, including items
/// rated *after* fold-in. Writers batch their inserts ([`ExclusionSet::
/// extend`]); the batcher takes one lock per top-k request.
#[derive(Default)]
pub struct ExclusionSet {
    inner: std::sync::Mutex<HashMap<u32, HashSet<u32>>>,
}

impl ExclusionSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed from a training matrix (the standard serve-time protocol).
    pub fn from_matrix(train: &crate::sparse::CooMatrix) -> Self {
        let set = Self::new();
        set.extend(train.entries().iter().map(|e| (e.u, e.v)));
        set
    }

    /// Record consumed `(user, item)` pairs (one lock for the whole batch).
    pub fn extend(&self, pairs: impl IntoIterator<Item = (u32, u32)>) {
        let mut g = self.inner.lock().expect("exclusion set poisoned");
        for (u, v) in pairs {
            g.entry(u).or_default().insert(v);
        }
    }

    /// Snapshot of user `u`'s excluded items.
    pub fn for_user(&self, u: u32) -> HashSet<u32> {
        self.inner
            .lock()
            .expect("exclusion set poisoned")
            .get(&u)
            .cloned()
            .unwrap_or_default()
    }
}

/// How the service picks its execution backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendMode {
    /// Load the XLA artifacts or fail to start.
    XlaRequired,
    /// Try XLA; fall back to the native backend if loading fails.
    Auto,
    /// Always use the native backend (no artifacts needed).
    NativeOnly,
}

/// Execution backend for batched predictions and top-k scans.
enum Backend {
    Xla(XlaRuntime),
    Native,
}

impl Backend {
    fn batch_size(&self) -> usize {
        match self {
            Backend::Xla(rt) => rt.shapes.b,
            Backend::Native => NATIVE_BATCH,
        }
    }

    /// r̂[lane] = ⟨mu[lane,:], nv[lane,:]⟩ over `B × d` gathered rows.
    fn predict_batch(&self, mu: &[f32], nv: &[f32], d: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Xla(rt) => rt.predict_batch(mu, nv),
            Backend::Native => Ok(mu
                .chunks_exact(d)
                .zip(nv.chunks_exact(d))
                .map(|(a, b)| crate::model::dot(a, b))
                .collect()),
        }
    }
}

/// Service statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Requests answered.
    pub served: u64,
    /// Backend batches executed.
    pub batches: u64,
    /// Top-k requests answered.
    pub topk_served: u64,
    /// Sum of batch occupancies (served / batches = mean batch size).
    pub occupancy_sum: u64,
    /// Distinct snapshot versions observed while serving (≥ 1 once any
    /// request was served; > 1 ⇒ factors were hot-swapped in-flight).
    pub versions_seen: u64,
    /// Snapshot version of the most recent batch.
    pub last_version: u64,
    /// Top-k requests shed at admission (bounded queue full). Counted on
    /// the submitting thread, folded into scrapes — see
    /// [`ServiceClient::top_k_within`].
    pub topk_shed: u64,
    /// Top-k requests whose deadline had already passed at dequeue
    /// (answered [`TopKAnswer::Overloaded`] without scanning).
    pub deadline_miss: u64,
}

impl ServiceStats {
    /// Mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }

    /// Pack for seqlock publication (field order is [`Self::from_array`]'s
    /// contract). `topk_shed` is excluded: it is counted at admission on
    /// client threads (a single shared atomic), not by the batcher.
    fn to_array(&self) -> [u64; 7] {
        [
            self.served,
            self.batches,
            self.topk_served,
            self.occupancy_sum,
            self.versions_seen,
            self.last_version,
            self.deadline_miss,
        ]
    }

    fn from_array(a: [u64; 7]) -> Self {
        ServiceStats {
            served: a[0],
            batches: a[1],
            topk_served: a[2],
            occupancy_sum: a[3],
            versions_seen: a[4],
            last_version: a[5],
            topk_shed: 0,
            deadline_miss: a[6],
        }
    }
}

/// Handle for submitting requests; cloneable across client threads.
///
/// The underlying queue is bounded ([`ServiceOptions::queue_cap`]):
/// blocking submissions backpressure when it is full, while
/// [`ServiceClient::top_k_within`] sheds with an explicit
/// [`TopKAnswer::Overloaded`]. Any clone can also scrape live
/// [`ServiceClient::stats`] (torn-free seqlock read).
///
/// ```
/// use a2psgd::coordinator::service::{PredictionService, ServiceOptions};
/// use a2psgd::model::Factors;
/// use a2psgd::model::snapshot::SnapshotStore;
/// use a2psgd::rng::Rng;
/// use std::sync::Arc;
///
/// let mut rng = Rng::new(1);
/// let store = Arc::new(SnapshotStore::new(Factors::init(10, 20, 8, 0.4, &mut rng)));
/// let svc = PredictionService::start_with_options(
///     std::path::PathBuf::new(), // native backend: no artifacts needed
///     store,
///     None,
///     ServiceOptions::native(),
/// )?;
/// let client = svc.client();
/// let r = client.predict(0, 0)?;
/// assert!((1.0..=5.0).contains(&r));
/// drop(client);
/// let stats = svc.shutdown();
/// assert_eq!(stats.served, 1);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Clone)]
pub struct ServiceClient {
    tx: mpsc::SyncSender<Request>,
    stats_cell: Arc<crate::obs::SeqCell<7>>,
    shed: Arc<AtomicU64>,
}

impl ServiceClient {
    /// Blocking point prediction.
    pub fn predict(&self, u: u32, v: u32) -> Result<f32> {
        let rx = self.predict_async(u, v)?;
        rx.recv().context("service dropped the request")
    }

    /// Fire a prediction and return the reply channel without waiting.
    /// Dropping the receiver is allowed; the service discards the answer.
    /// Blocks only while the bounded request queue is full (backpressure).
    pub fn predict_async(&self, u: u32, v: u32) -> Result<mpsc::Receiver<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Predict { u, v, reply })
            .ok()
            .context("service stopped")?;
        Ok(rx)
    }

    /// Blocking top-k recommendation (items the user rated in training are
    /// excluded when the service was built with a training matrix). No
    /// deadline, no shedding: waits for queue space and for the scan.
    pub fn top_k(&self, u: u32, k: usize) -> Result<Vec<(u32, f32)>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::TopK { u, k, deadline: None, reply })
            .ok()
            .context("service stopped")?;
        match rx.recv().context("service dropped the request")? {
            TopKAnswer::Ranked(top) => Ok(top),
            // Unreachable for deadline-free blocking submissions, but a
            // defensive answer beats a panic on a protocol change.
            TopKAnswer::Overloaded => anyhow::bail!("service overloaded"),
        }
    }

    /// Deadline-aware top-k under admission control: returns
    /// [`TopKAnswer::Overloaded`] immediately when the bounded queue is
    /// full (shed at admission), and the batcher answers `Overloaded`
    /// without scanning when `deadline` has already passed at dequeue.
    ///
    /// `deadline` is measured from the call (`None` = no deadline, still
    /// sheds on a full queue). This is the wire front end's serving path.
    pub fn top_k_within(
        &self,
        u: u32,
        k: usize,
        deadline: Option<Duration>,
    ) -> Result<TopKAnswer> {
        let (reply, rx) = mpsc::channel();
        let deadline = deadline.map(|d| Instant::now() + d);
        match self.tx.try_send(Request::TopK { u, k, deadline, reply }) {
            Ok(()) => rx.recv().context("service dropped the request"),
            Err(mpsc::TrySendError::Full(_)) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                crate::obs::add(crate::obs::Ctr::ServeShed, 1);
                Ok(TopKAnswer::Overloaded)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => anyhow::bail!("service stopped"),
        }
    }

    /// Live stats scrape, torn-free (see [`PredictionService::stats`]);
    /// available from any client clone so e.g. the wire front end can
    /// answer `STATS` without holding the service itself.
    pub fn stats(&self) -> ServiceStats {
        let mut s = ServiceStats::from_array(self.stats_cell.read());
        s.topk_shed = self.shed.load(Ordering::Relaxed);
        s
    }

    /// Submit many predictions as **one** enqueued batch and wait for all.
    ///
    /// The batcher slices the pair list straight into full backend batches
    /// — one channel round-trip and `⌈len/B⌉` backend calls total, instead
    /// of N per-request sends, N reply channels, and whatever partial
    /// batches the drain window happened to cut.
    pub fn predict_many(&self, pairs: &[(u32, u32)]) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::PredictBatch { pairs: pairs.to_vec(), reply })
            .ok()
            .context("service stopped")?;
        rx.recv().context("service dropped the request")
    }
}

/// Serving policy knobs for [`PredictionService::start_with_options`].
#[derive(Clone, Copy, Debug)]
pub struct ServiceOptions {
    /// Rating-scale clamp applied to every point prediction.
    pub clamp: (f32, f32),
    /// Max time a non-full batch waits for more traffic before launching.
    pub max_wait: Duration,
    /// Backend selection policy.
    pub mode: BackendMode,
    /// Quantized top-k index mode; `None` scans the f32 item matrix.
    /// The index is rebuilt per published snapshot version.
    pub quant: Option<QuantMode>,
    /// Bound of the request queue: blocking submissions backpressure
    /// beyond it, [`ServiceClient::top_k_within`] sheds. Must be ≥ 1.
    pub queue_cap: usize,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            clamp: (1.0, 5.0),
            max_wait: Duration::from_millis(1),
            mode: BackendMode::Auto,
            quant: None,
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }
}

impl ServiceOptions {
    /// Defaults on the native backend with the int8 quantized index — the
    /// portable serving configuration (`a2psgd serve --listen`).
    pub fn native() -> Self {
        ServiceOptions {
            mode: BackendMode::NativeOnly,
            quant: Some(QuantMode::Int8),
            ..Self::default()
        }
    }
}

/// The running service; shutting down requires all external
/// [`ServiceClient`] clones to be dropped first (their senders keep the
/// worker's receive loop alive).
pub struct PredictionService {
    client: ServiceClient,
    worker: std::thread::JoinHandle<ServiceStats>,
}

impl PredictionService {
    /// Spawn the batcher thread over trained factors (XLA artifacts
    /// required; see [`PredictionService::start_over_store`] for hot-swap
    /// serving and backend selection).
    ///
    /// `max_wait` bounds added latency when traffic is sparse: a non-full
    /// batch launches once the oldest queued request has waited this long.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        factors: Factors,
        clamp: (f32, f32),
        max_wait: Duration,
    ) -> Result<Self> {
        Self::start_with_exclusions(artifacts_dir, factors, clamp, max_wait, None)
    }

    /// [`PredictionService::start`] plus a training matrix whose items are
    /// excluded from each user's top-k candidates (standard protocol).
    pub fn start_with_exclusions(
        artifacts_dir: std::path::PathBuf,
        factors: Factors,
        clamp: (f32, f32),
        max_wait: Duration,
        train: Option<crate::sparse::CooMatrix>,
    ) -> Result<Self> {
        let store = Arc::new(SnapshotStore::new(factors));
        let exclusions = train.map(|t| Arc::new(ExclusionSet::from_matrix(&t)));
        Self::start_over_store(artifacts_dir, store, clamp, max_wait, exclusions, BackendMode::XlaRequired)
    }

    /// Spawn the batcher over a shared [`SnapshotStore`]: the service pins
    /// the current snapshot per batch, so whoever holds the store can
    /// publish refreshed factors with zero service downtime. Compatibility
    /// wrapper over [`PredictionService::start_with_options`] (no quantized
    /// index, default queue bound).
    pub fn start_over_store(
        artifacts_dir: std::path::PathBuf,
        store: Arc<SnapshotStore>,
        clamp: (f32, f32),
        max_wait: Duration,
        exclusions: Option<Arc<ExclusionSet>>,
        mode: BackendMode,
    ) -> Result<Self> {
        Self::start_with_options(
            artifacts_dir,
            store,
            exclusions,
            ServiceOptions { clamp, max_wait, mode, ..ServiceOptions::default() },
        )
    }

    /// Spawn the batcher over a shared [`SnapshotStore`] with the full
    /// serving policy ([`ServiceOptions`]): backend selection, bounded
    /// queue, and the per-snapshot quantized top-k index.
    ///
    /// The PJRT runtime is constructed *inside* the worker thread (the xla
    /// crate's client is `!Send`), so this takes the artifacts directory
    /// and reports load/compile errors synchronously through a startup
    /// channel.
    pub fn start_with_options(
        artifacts_dir: std::path::PathBuf,
        store: Arc<SnapshotStore>,
        exclusions: Option<Arc<ExclusionSet>>,
        opts: ServiceOptions,
    ) -> Result<Self> {
        anyhow::ensure!(opts.queue_cap >= 1, "queue_cap must be ≥ 1");
        let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_cap);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats_cell = Arc::new(crate::obs::SeqCell::<7>::new());
        let shed = Arc::new(AtomicU64::new(0));
        let worker_cell = Arc::clone(&stats_cell);
        let worker = std::thread::spawn(move || {
            let backend = match opts.mode {
                BackendMode::NativeOnly => Backend::Native,
                BackendMode::XlaRequired => match XlaRuntime::load(&artifacts_dir) {
                    Ok(rt) => Backend::Xla(rt),
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return ServiceStats::default();
                    }
                },
                BackendMode::Auto => match XlaRuntime::load(&artifacts_dir) {
                    Ok(rt) => Backend::Xla(rt),
                    Err(e) => {
                        eprintln!("service: XLA backend unavailable ({e:#}); using native backend");
                        Backend::Native
                    }
                },
            };
            let _ = ready_tx.send(Ok(()));
            run_batcher(backend, store, &opts, exclusions, rx, &worker_cell)
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(PredictionService {
                client: ServiceClient { tx, stats_cell, shed },
                worker,
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                anyhow::bail!("service worker died during startup")
            }
        }
    }

    /// A client handle.
    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    /// Live stats scrape, torn-free: the batcher publishes every counter
    /// mutation as one seqlock unit, so a read concurrent with a batch
    /// still sees `served`/`batches`/`occupancy_sum` move together —
    /// never `batches` incremented but its predictions not yet counted.
    /// (`topk_shed` is the one exception: counted at admission on client
    /// threads, it is folded in from its own atomic.)
    pub fn stats(&self) -> ServiceStats {
        self.client.stats()
    }

    /// Stop and collect stats (consumes the service). All other client
    /// clones must already be dropped, or this blocks until they are.
    pub fn shutdown(self) -> ServiceStats {
        let PredictionService { client, worker } = self;
        let shed = Arc::clone(&client.shed);
        drop(client); // close our sender so the worker's recv errors out
        let mut stats = worker.join().expect("service worker panicked");
        stats.topk_shed = shed.load(Ordering::Relaxed);
        stats
    }
}

/// Top-k state cached across batches: the padded item matrix is rebuilt
/// only when the snapshot version changes (XLA backend only).
struct TopKCache {
    version: u64,
    n_padded: Vec<f32>,
}

/// Quantized-index cache, keyed by snapshot version: the index is rebuilt
/// by the first top-k request that observes a new published generation
/// (one linear pass over the item matrix), then reused for every scan
/// served from that snapshot.
struct QuantCache {
    version: u64,
    index: QuantizedIndex,
}

/// The single implementation of batch execution shared by the live drain
/// path and pre-assembled [`Request::PredictBatch`] submissions: long-lived
/// `B × D` gather scratch plus the answer policy (zero unknown lanes,
/// midpoint for unknown nodes, clamp to the rating scale, stats
/// accounting). Keeping it in one place means `predict` and `predict_many`
/// can never drift apart semantically.
struct BatchExec {
    d: usize,
    clamp: (f32, f32),
    midpoint: f32,
    mu: Vec<f32>,
    nv: Vec<f32>,
    known: Vec<bool>,
}

impl BatchExec {
    fn new(b: usize, d: usize, clamp: (f32, f32)) -> Self {
        BatchExec {
            d,
            clamp,
            midpoint: 0.5 * (clamp.0 + clamp.1),
            mu: vec![0f32; b * d],
            nv: vec![0f32; b * d],
            known: vec![false; b],
        }
    }

    /// Gather rows for ≤B `pairs` under `f`, run one backend call, and
    /// return the final answer per pair (in order).
    fn execute(
        &mut self,
        backend: &Backend,
        f: &Factors,
        pairs: &[(u32, u32)],
        stats: &mut ServiceStats,
    ) -> Result<Vec<f32>> {
        let d = self.d;
        debug_assert!(pairs.len() * d <= self.mu.len());
        debug_assert_eq!(f.d(), d, "hot swap must preserve the feature dimension");
        // Known lanes are fully overwritten by the gather; only unknown
        // lanes and the unused tail need zeroing (their prediction is
        // replaced by the midpoint / discarded).
        self.known.fill(false);
        for (lane, &(u, v)) in pairs.iter().enumerate() {
            let lo = lane * d;
            if u < f.nrows() && v < f.ncols() {
                self.mu[lo..lo + d].copy_from_slice(f.m_row(u));
                self.nv[lo..lo + d].copy_from_slice(f.n_row(v));
                self.known[lane] = true;
            } else {
                self.mu[lo..lo + d].iter_mut().for_each(|x| *x = 0.0);
                self.nv[lo..lo + d].iter_mut().for_each(|x| *x = 0.0);
            }
        }
        for lane in pairs.len()..self.known.len() {
            let lo = lane * d;
            self.mu[lo..lo + d].iter_mut().for_each(|x| *x = 0.0);
            self.nv[lo..lo + d].iter_mut().for_each(|x| *x = 0.0);
        }
        let preds = backend.predict_batch(&self.mu, &self.nv, d)?;
        stats.batches += 1;
        stats.occupancy_sum += pairs.len() as u64;
        stats.served += pairs.len() as u64;
        crate::obs::add(crate::obs::Ctr::ServeBatches, 1);
        crate::obs::add(crate::obs::Ctr::ServeRequests, pairs.len() as u64);
        Ok((0..pairs.len())
            .map(|lane| {
                if self.known[lane] {
                    preds[lane].clamp(self.clamp.0, self.clamp.1)
                } else {
                    self.midpoint
                }
            })
            .collect())
    }
}

fn run_batcher(
    backend: Backend,
    store: Arc<SnapshotStore>,
    opts: &ServiceOptions,
    exclusions: Option<Arc<ExclusionSet>>,
    rx: mpsc::Receiver<Request>,
    stats_cell: &crate::obs::SeqCell<7>,
) -> ServiceStats {
    let b = backend.batch_size();
    let d = store.load().factors().d();
    let max_wait = opts.max_wait;
    let mut stats = ServiceStats::default();
    let mut exec = BatchExec::new(b, d, opts.clamp);
    let mut topk_cache: Option<TopKCache> = None;
    let mut quant_cache: Option<QuantCache> = None;
    // Queued point predictions carry their receipt time for the latency
    // histogram (latency = receipt → reply, drain window included).
    let mut batch: Vec<(u32, u32, mpsc::Sender<f32>, Instant)> = Vec::with_capacity(b);
    loop {
        // Block for the first request; then drain greedily until B or timeout.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => break, // all clients dropped
        };
        let mut pending = Some(first);
        let deadline = Instant::now() + max_wait;
        loop {
            let received = Instant::now();
            match pending.take() {
                Some(Request::Predict { u, v, reply }) => batch.push((u, v, reply, received)),
                Some(Request::PredictBatch { pairs, reply }) => {
                    // A pre-assembled batch needs no drain window: execute
                    // full backend batches straight from the pair list,
                    // under one pinned snapshot.
                    let snap = store.load();
                    observe_version(&mut stats, &snap);
                    let f = snap.factors();
                    let mut out = Vec::with_capacity(pairs.len());
                    for chunk in pairs.chunks(b) {
                        match exec.execute(&backend, f, chunk, &mut stats) {
                            Ok(answers) => out.extend(answers),
                            Err(_) => {
                                // Backend failure: stop service.
                                stats_cell.publish(&stats.to_array());
                                return stats;
                            }
                        }
                    }
                    let _ = reply.send(out);
                    observe_latency(received);
                    stats_cell.publish(&stats.to_array());
                }
                Some(Request::TopK { u, k, deadline, reply }) => {
                    // Per-request deadline: a request that would be served
                    // late is shed *before* paying for the catalog scan.
                    if deadline.is_some_and(|dl| Instant::now() > dl) {
                        let _ = reply.send(TopKAnswer::Overloaded);
                        stats.deadline_miss += 1;
                        crate::obs::add(crate::obs::Ctr::ServeDeadlineMiss, 1);
                        stats_cell.publish(&stats.to_array());
                        continue;
                    }
                    // Top-k is a whole-catalog scan — served immediately,
                    // not batched with point predictions. Exclusions are
                    // re-read per request: the online trainer keeps adding
                    // streamed interactions to the shared set.
                    let snap = store.load();
                    observe_version(&mut stats, &snap);
                    let ex = exclusions
                        .as_ref()
                        .map(|e| e.for_user(u))
                        .unwrap_or_default();
                    match serve_top_k(
                        &backend,
                        &snap,
                        opts.quant,
                        &mut topk_cache,
                        &mut quant_cache,
                        u,
                        k,
                        &ex,
                    ) {
                        Ok(top) => {
                            let _ = reply.send(TopKAnswer::Ranked(top));
                            stats.topk_served += 1;
                            crate::obs::add(crate::obs::Ctr::ServeRequests, 1);
                            observe_latency(received);
                            stats_cell.publish(&stats.to_array());
                        }
                        Err(_) => {
                            stats_cell.publish(&stats.to_array());
                            return stats;
                        }
                    }
                }
                None => {}
            }
            if batch.len() >= b {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => pending = Some(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if batch.is_empty() {
            continue; // the window held only top-k traffic
        }
        // Pin the current snapshot for this whole batch (hot-swap boundary).
        let snap = store.load();
        observe_version(&mut stats, &snap);
        let pairs: Vec<(u32, u32)> = batch.iter().map(|&(u, v, _, _)| (u, v)).collect();
        let answers = match exec.execute(&backend, snap.factors(), &pairs, &mut stats) {
            Ok(a) => a,
            Err(_) => break, // backend failure: drop in-flight, stop service
        };
        for ((_, _, reply, received), p) in batch.drain(..).zip(answers) {
            let _ = reply.send(p); // client may have gone away; fine
            observe_latency(received);
        }
        stats_cell.publish(&stats.to_array());
    }
    stats_cell.publish(&stats.to_array());
    stats
}

/// Record one request's receipt→reply latency into the log2 histogram.
fn observe_latency(received: Instant) {
    if crate::obs::metrics_enabled() {
        crate::obs::observe(
            crate::obs::Hist::ServiceLatencyNs,
            received.elapsed().as_nanos() as u64,
        );
    }
}

fn observe_version(stats: &mut ServiceStats, snap: &FactorSnapshot) {
    if snap.version() != stats.last_version {
        stats.last_version = snap.version();
        stats.versions_seen += 1;
    }
}

/// Top-k for one user under the pinned snapshot. With a quantized mode
/// configured, the scan runs over the per-snapshot [`QuantizedIndex`]
/// (rebuilt on version change) through the dispatched quantized kernels.
/// Otherwise the XLA `recommend` artifact is used when the catalog fits
/// its padding, and the f32 native scan covers everything else (native
/// backend, unknown user, or a catalog grown past the padding).
#[allow(clippy::too_many_arguments)]
fn serve_top_k(
    backend: &Backend,
    snap: &FactorSnapshot,
    quant: Option<QuantMode>,
    cache: &mut Option<TopKCache>,
    quant_cache: &mut Option<QuantCache>,
    u: u32,
    k: usize,
    seen: &HashSet<u32>,
) -> Result<Vec<(u32, f32)>> {
    let f = snap.factors();
    if u >= f.nrows() {
        return Ok(Vec::new()); // unknown user: no candidates yet
    }
    if let Some(mode) = quant {
        let fresh = match quant_cache {
            Some(c) => c.version != snap.version(),
            None => true,
        };
        if fresh {
            *quant_cache = Some(QuantCache {
                version: snap.version(),
                index: QuantizedIndex::build(f, mode),
            });
        }
        let index = &quant_cache.as_ref().expect("cache filled above").index;
        return Ok(index.top_k(f.m_row(u), k, seen));
    }
    if let Backend::Xla(rt) = backend {
        let fits = f.n.len() <= rt.shapes.v * f.d();
        if fits {
            let fresh = match cache {
                Some(c) => c.version != snap.version(),
                None => true,
            };
            if fresh {
                *cache = Some(TopKCache {
                    version: snap.version(),
                    n_padded: crate::runtime::pad_item_matrix(f, rt.shapes.v),
                });
            }
            let n_padded = &cache.as_ref().expect("cache filled above").n_padded;
            return rt.top_k(f, n_padded, u, k, seen);
        }
    }
    // Native scan.
    let mu = f.m_row(u);
    let scored: Vec<(u32, f32)> = (0..f.ncols())
        .filter(|v| !seen.contains(v))
        .map(|v| (v, crate::model::dot(mu, f.n_row(v))))
        .collect();
    Ok(crate::metrics::topn::take_top_k(scored, k))
}

// Integration coverage: rust/tests/integration_service.rs (XLA backend,
// requires artifacts) and rust/tests/integration_stream.rs (native backend,
// batcher edge cases, hot-swap protocol).

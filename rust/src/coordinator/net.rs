//! Line-protocol TCP front end for the prediction service (`a2psgd serve
//! --listen`): std-only sockets, connections served by the persistent
//! [`WorkerPool`].
//!
//! # Wire protocol
//!
//! One request per line, one reply line per request, UTF-8, `\n`-terminated
//! (the full grammar with examples lives in SERVING.md):
//!
//! ```text
//! → TOPK <user> <k> [deadline_ms]     ← OK <item>:<score> …  |  OVERLOADED
//! → PREDICT <user> <item>             ← OK <score>
//! → STATS                             ← one-line JSON (ServiceStats)
//! → QUIT                              ← (connection closes)
//! anything else                       ← ERR <message>
//! ```
//!
//! `TOPK` runs through [`ServiceClient::top_k_within`], so the bounded
//! queue and per-request deadline semantics apply verbatim: a full queue
//! or an expired deadline answers `OVERLOADED` instead of queueing the
//! connection unboundedly. Malformed lines answer `ERR …` and keep the
//! connection open; the server never disconnects a client for a bad
//! request.
//!
//! # Concurrency & shutdown
//!
//! A driver thread parks the [`WorkerPool`] workers in a shared
//! `accept` loop (the listener is a kernel-side accept queue — sharing it
//! across threads *is* the load balancer). Each worker serves one
//! connection at a time, line by line. [`TopKServer::shutdown`] flips a
//! stop flag and then wakes every worker with a throwaway local
//! connection, so no worker stays parked in `accept` forever.

use super::service::{ServiceClient, ServiceStats, TopKAnswer};
use crate::runtime::pool::WorkerPool;
use crate::Result;
use anyhow::Context;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Wire front-end policy.
#[derive(Clone, Copy, Debug)]
pub struct NetOptions {
    /// Worker threads accepting and serving connections.
    pub threads: usize,
    /// Default per-request deadline applied to `TOPK` lines that do not
    /// carry their own `deadline_ms` (`None` = no deadline).
    pub deadline: Option<Duration>,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions { threads: 2, deadline: None }
    }
}

/// A running TCP front end; dropping it without [`TopKServer::shutdown`]
/// detaches the acceptor threads (they exit with the process).
pub struct TopKServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: usize,
    driver: Option<std::thread::JoinHandle<()>>,
}

impl TopKServer {
    /// Start serving `listener`'s connections against `client`.
    ///
    /// Bind with port 0 to let the OS pick a free port —
    /// [`TopKServer::addr`] reports the resolved address:
    ///
    /// ```no_run
    /// use a2psgd::coordinator::net::{NetOptions, TopKServer};
    /// # fn demo(client: a2psgd::coordinator::service::ServiceClient) -> anyhow::Result<()> {
    /// let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    /// let server = TopKServer::start(listener, client, NetOptions::default())?;
    /// println!("serving on {}", server.addr());
    /// # Ok(()) }
    /// ```
    pub fn start(listener: TcpListener, client: ServiceClient, opts: NetOptions) -> Result<Self> {
        anyhow::ensure!(opts.threads >= 1, "net front end needs ≥ 1 thread");
        let addr = listener.local_addr().context("resolving listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_driver = Arc::clone(&stop);
        let driver = std::thread::spawn(move || {
            let pool = WorkerPool::new(opts.threads);
            let listener = &listener;
            let client = &client;
            let stop = &stop_driver;
            pool.run(|_tid| accept_loop(listener, client, stop, opts.deadline));
        });
        Ok(TopKServer { addr, stop, threads: opts.threads, driver: Some(driver) })
    }

    /// The bound address (resolved port when the listener bound port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake parked acceptors, and join the workers.
    /// In-flight connections finish their current line first.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        // Each wake connection unparks at most one worker's accept() —
        // send one per pool thread, then sleep briefly before retrying.
        // (This used to be an unbounded connect storm with yield_now(),
        // hammering the listener — and every raced real client — until
        // the driver happened to finish.) Failure is fine: a listener
        // that is already gone means nobody is parked.
        if let Some(driver) = self.driver.take() {
            while !driver.is_finished() {
                for _ in 0..self.threads {
                    let _ = TcpStream::connect(self.addr);
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            let _ = driver.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    client: &ServiceClient,
    stop: &AtomicBool,
    deadline: Option<Duration>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stop.load(Ordering::Acquire) {
                    // Raced accept during shutdown: usually a wake-up
                    // connection (closes immediately → EOF), but it can
                    // be a *real* client that connected just before the
                    // stop flag flipped. Honor the shutdown contract —
                    // "in-flight connections finish their current line" —
                    // by serving whatever it already sent under a short
                    // read timeout instead of dropping it replyless.
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
                    let _ = serve_conn(stream, client, deadline);
                    return;
                }
                // A torn connection only ends that connection.
                let _ = serve_conn(stream, client, deadline);
            }
            Err(_) => {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                // Transient accept error (e.g. EMFILE): brief pause, retry.
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Serve one connection until EOF / `QUIT` / an I/O error.
fn serve_conn(stream: TcpStream, client: &ServiceClient, deadline: Option<Duration>) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/reply traffic: don't batch
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).context("reading request line")? == 0 {
            return Ok(()); // EOF
        }
        let reply = match answer_line(line.trim(), client, deadline) {
            Some(r) => r,
            None => return Ok(()), // QUIT
        };
        out.write_all(reply.as_bytes()).context("writing reply")?;
        out.write_all(b"\n").context("writing reply terminator")?;
    }
}

/// Parse one request line and produce its reply line (`None` = `QUIT`).
/// Split out of the connection loop so the protocol is unit-testable
/// without sockets.
fn answer_line(line: &str, client: &ServiceClient, deadline: Option<Duration>) -> Option<String> {
    let mut parts = line.split_ascii_whitespace();
    let verb = parts.next().unwrap_or("");
    let reply = match verb.to_ascii_uppercase().as_str() {
        "TOPK" => topk_line(parts, client, deadline),
        "PREDICT" => predict_line(parts, client),
        "STATS" => Ok(stats_json(&client.stats())),
        "QUIT" => return None,
        "" => Err("empty request".to_string()),
        other => Err(format!("unknown verb {other:?} (TOPK|PREDICT|STATS|QUIT)")),
    };
    Some(match reply {
        Ok(r) => r,
        Err(msg) => format!("ERR {msg}"),
    })
}

fn topk_line<'a>(
    mut parts: impl Iterator<Item = &'a str>,
    client: &ServiceClient,
    default_deadline: Option<Duration>,
) -> std::result::Result<String, String> {
    let u: u32 = parse_field(parts.next(), "user")?;
    let k: usize = parse_field(parts.next(), "k")?;
    let deadline = match parts.next() {
        Some(ms) => Some(Duration::from_millis(parse_field(Some(ms), "deadline_ms")?)),
        None => default_deadline,
    };
    if parts.next().is_some() {
        return Err("TOPK takes at most 3 fields: user k [deadline_ms]".to_string());
    }
    match client.top_k_within(u, k, deadline) {
        Ok(TopKAnswer::Ranked(top)) => {
            let mut s = String::from("OK");
            for (v, score) in top {
                s.push_str(&format!(" {v}:{score:.4}"));
            }
            Ok(s)
        }
        Ok(TopKAnswer::Overloaded) => Ok("OVERLOADED".to_string()),
        Err(e) => Err(format!("{e:#}")),
    }
}

fn predict_line<'a>(
    mut parts: impl Iterator<Item = &'a str>,
    client: &ServiceClient,
) -> std::result::Result<String, String> {
    let u: u32 = parse_field(parts.next(), "user")?;
    let v: u32 = parse_field(parts.next(), "item")?;
    if parts.next().is_some() {
        return Err("PREDICT takes exactly 2 fields: user item".to_string());
    }
    match client.predict(u, v) {
        Ok(p) => Ok(format!("OK {p:.4}")),
        Err(e) => Err(format!("{e:#}")),
    }
}

fn parse_field<T: std::str::FromStr>(
    field: Option<&str>,
    name: &str,
) -> std::result::Result<T, String> {
    field
        .ok_or_else(|| format!("missing field {name:?}"))?
        .parse()
        .map_err(|_| format!("bad {name}: {:?}", field.unwrap_or("")))
}

/// One-line JSON for the `STATS` verb (same field names as
/// [`ServiceStats`]).
fn stats_json(s: &ServiceStats) -> String {
    crate::bench_harness::json::Obj::new()
        .int("served", s.served)
        .int("batches", s.batches)
        .int("topk_served", s.topk_served)
        .int("occupancy_sum", s.occupancy_sum)
        .int("versions_seen", s.versions_seen)
        .int("last_version", s.last_version)
        .int("topk_shed", s.topk_shed)
        .int("deadline_miss", s.deadline_miss)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{PredictionService, ServiceOptions};
    use crate::model::snapshot::SnapshotStore;
    use crate::model::Factors;
    use crate::rng::Rng;

    fn native_service() -> PredictionService {
        let mut rng = Rng::new(11);
        let store = Arc::new(SnapshotStore::new(Factors::init(20, 50, 8, 0.4, &mut rng)));
        PredictionService::start_with_options(
            std::path::PathBuf::new(),
            store,
            None,
            ServiceOptions::native(),
        )
        .expect("native service starts without artifacts")
    }

    #[test]
    fn protocol_lines_parse_and_answer() {
        let svc = native_service();
        let client = svc.client();
        let topk = answer_line("TOPK 0 3", &client, None).unwrap();
        assert!(topk.starts_with("OK "), "{topk}");
        assert_eq!(topk.split_whitespace().count(), 4, "3 item:score pairs: {topk}");
        let pred = answer_line("PREDICT 0 1", &client, None).unwrap();
        assert!(pred.starts_with("OK "), "{pred}");
        let p: f32 = pred[3..].parse().unwrap();
        assert!((1.0..=5.0).contains(&p));
        let stats = answer_line("STATS", &client, None).unwrap();
        assert!(stats.contains("\"topk_served\":1"), "{stats}");
        assert!(stats.contains("\"served\":1"), "{stats}");
        assert!(answer_line("QUIT", &client, None).is_none());
        drop(client);
        svc.shutdown();
    }

    #[test]
    fn protocol_rejects_malformed_lines_without_closing() {
        let svc = native_service();
        let client = svc.client();
        for bad in [
            "",
            "FROB 1 2",
            "TOPK",
            "TOPK x 3",
            "TOPK 0 3 100 extra",
            "PREDICT 0",
            "PREDICT 0 y",
        ] {
            let reply = answer_line(bad, &client, None).unwrap();
            assert!(reply.starts_with("ERR "), "{bad:?} → {reply}");
        }
        // Lowercase verbs are accepted (case-insensitive).
        assert!(answer_line("topk 0 2", &client, None).unwrap().starts_with("OK"));
        drop(client);
        svc.shutdown();
    }

    #[test]
    fn expired_wire_deadline_answers_overloaded() {
        let svc = native_service();
        let client = svc.client();
        // deadline_ms = 0: already expired by the time the batcher
        // dequeues it — deterministic Overloaded.
        let reply = answer_line("TOPK 0 3 0", &client, None).unwrap();
        assert_eq!(reply, "OVERLOADED");
        drop(client);
        let stats = svc.shutdown();
        assert_eq!(stats.deadline_miss, 1);
        assert_eq!(stats.topk_served, 0);
    }

    #[test]
    fn server_answers_over_tcp_and_shuts_down() {
        let svc = native_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            TopKServer::start(listener, svc.client(), NetOptions { threads: 2, deadline: None })
                .unwrap();
        let addr = server.addr();
        let mut done = Vec::new();
        std::thread::scope(|s| {
            for t in 0..3u32 {
                done.push(s.spawn(move || {
                    let stream = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut w = stream;
                    let mut line = String::new();
                    for i in 0..5u32 {
                        writeln!(w, "TOPK {} 4", (t * 5 + i) % 20).unwrap();
                        line.clear();
                        reader.read_line(&mut line).unwrap();
                        assert!(line.starts_with("OK "), "{line}");
                    }
                    writeln!(w, "QUIT").unwrap();
                }));
            }
        });
        server.shutdown();
        let stats = svc.shutdown();
        assert_eq!(stats.topk_served, 15);
    }

    /// Regression: a *real* client accepted during shutdown used to be
    /// dropped without a reply (the raced-accept path returned straight
    /// away), contradicting the "in-flight connections finish their
    /// current line" contract. Stage the exact interleaving: a worker is
    /// parked in `accept()`, the stop flag flips, and only then does a
    /// client connect and send a line — `accept()` returns a live
    /// connection with `stop` already set, and the client must still get
    /// its reply line before the connection closes.
    #[test]
    fn raced_client_during_shutdown_gets_its_reply() {
        let svc = native_service();
        let client = svc.client();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let acceptor = s.spawn(|| accept_loop(&listener, &client, &stop, None));
            // Let the acceptor pass the while-check and park in accept().
            std::thread::sleep(Duration::from_millis(100));
            // Blocking accept() does not poll the flag, so the acceptor
            // stays parked and the next connection hits the raced path.
            stop.store(true, Ordering::Release);
            let stream = TcpStream::connect(addr).unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(5)))
                .unwrap();
            let mut w = stream.try_clone().unwrap();
            writeln!(w, "PREDICT 0 1").unwrap();
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            let n = reader.read_line(&mut line).unwrap_or(0);
            assert!(
                n > 0 && line.starts_with("OK "),
                "raced client must get its reply before close, got {n} bytes: {line:?}"
            );
            acceptor.join().unwrap();
        });
        drop(client);
        svc.shutdown();
    }

    /// Shutdown liveness under concurrent connect load: clients hammer
    /// the listener with connects and requests while shutdown runs. The
    /// paced per-thread wake (versus the old unbounded connect storm)
    /// must still finish promptly, and no client may observe a panic —
    /// only answered lines or a clean close.
    #[test]
    fn shutdown_completes_under_concurrent_connect_load() {
        let svc = native_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            TopKServer::start(listener, svc.client(), NetOptions { threads: 2, deadline: None })
                .unwrap();
        let addr = server.addr();
        let quit = Arc::new(AtomicBool::new(false));
        let mut hammers = Vec::new();
        for t in 0..4u32 {
            let quit = Arc::clone(&quit);
            hammers.push(std::thread::spawn(move || {
                let mut answered = 0u32;
                while !quit.load(Ordering::Acquire) {
                    let Ok(stream) = TcpStream::connect(addr) else { break };
                    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
                    let Ok(mut w) = stream.try_clone() else { continue };
                    if writeln!(w, "TOPK {} 2", t % 20).is_err() {
                        continue; // server already gone — clean close
                    }
                    let mut reader = BufReader::new(stream);
                    let mut line = String::new();
                    match reader.read_line(&mut line) {
                        Ok(n) if n > 0 => {
                            assert!(
                                line.starts_with("OK ") || line.starts_with("OVERLOADED"),
                                "{line:?}"
                            );
                            answered += 1;
                        }
                        // EOF or reset: raced the shutdown — acceptable.
                        _ => {}
                    }
                }
                answered
            }));
        }
        // Let the hammers build up real load, then shut down under it.
        std::thread::sleep(Duration::from_millis(100));
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown stalled under connect load: {:?}",
            t0.elapsed()
        );
        quit.store(true, Ordering::Release);
        let answered: u32 = hammers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(answered > 0, "load threads never got a single answer");
        let stats = svc.shutdown();
        // Every answered line was either served or shed (OVERLOADED).
        assert!(stats.topk_served + stats.topk_shed >= answered as u64);
    }
}

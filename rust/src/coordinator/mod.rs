//! Leader-side orchestration: resolve datasets, run (multi-seed) training
//! studies, emit the paper's tables/series, and host the post-training
//! prediction service.

pub mod net;
pub mod service;
pub mod tune;

use crate::data::{synthetic, Dataset};
use crate::engine::{train, EngineKind, TrainConfig, TrainReport};
use crate::metrics::MeanStd;
use crate::Result;
use anyhow::Context;
use std::path::Path;

/// Resolve a dataset key (`small`/`medium`/`ml1m`/`epinions`), a ratings
/// file path, or a packed `.a2ps` shard directory.
pub fn resolve_dataset(key: &str, seed: u64) -> Result<Dataset> {
    Ok(match key {
        "small" => synthetic::small(seed),
        "medium" => synthetic::medium(seed),
        "ml1m" | "ml1m-twin" => synthetic::movielens_like(seed),
        "epinions" | "epinions-twin" => synthetic::epinions_like(seed),
        path => {
            let p = Path::new(path);
            if crate::data::shard::is_shard_dir(p) {
                let mut src = crate::data::ingest::ShardDirSource::open(p)?;
                crate::data::ingest::materialize(&mut src, path, 0.3, seed)
                    .with_context(|| format!("loading shard directory {path}"))?
            } else {
                crate::data::loader::load_file(p, path, 0.3, seed)
                    .with_context(|| format!("{key:?} is not a dataset key; tried loading as file"))?
            }
        }
    })
}

/// Outcome of a multi-seed study for one (engine, dataset) cell.
#[derive(Clone, Debug)]
pub struct StudyCell {
    /// Engine.
    pub engine: EngineKind,
    /// Best-RMSE aggregate across seeds (Table III row).
    pub rmse: MeanStd,
    /// Best-MAE aggregate.
    pub mae: MeanStd,
    /// RMSE-time aggregate (Table IV row).
    pub rmse_time: MeanStd,
    /// MAE-time aggregate.
    pub mae_time: MeanStd,
    /// Mean updates/second.
    pub updates_per_sec: f64,
    /// One representative run (first seed) for convergence curves.
    pub representative: TrainReport,
}

/// Run `seeds.len()` independent runs of one engine and aggregate.
pub fn run_cell(data_key: &str, engine: EngineKind, seeds: &[u64], mk_cfg: &dyn Fn(EngineKind, &Dataset) -> TrainConfig) -> Result<StudyCell> {
    assert!(!seeds.is_empty());
    let mut rmse = Vec::new();
    let mut mae = Vec::new();
    let mut rmse_t = Vec::new();
    let mut mae_t = Vec::new();
    let mut ups = Vec::new();
    let mut representative = None;
    for &seed in seeds {
        // Dataset resampled per seed — the paper's ± spread covers both
        // split randomness and training stochasticity.
        let data = resolve_dataset(data_key, seed)?;
        let cfg = mk_cfg(engine, &data).seed(seed);
        let report = train(&data, &cfg)?;
        rmse.push(report.best_rmse());
        mae.push(report.best_mae());
        rmse_t.push(report.rmse_time());
        mae_t.push(report.mae_time());
        ups.push(report.updates_per_sec());
        if representative.is_none() {
            representative = Some(report);
        }
    }
    Ok(StudyCell {
        engine,
        rmse: MeanStd::from(&rmse),
        mae: MeanStd::from(&mae),
        rmse_time: MeanStd::from(&rmse_t),
        mae_time: MeanStd::from(&mae_t),
        updates_per_sec: ups.iter().sum::<f64>() / ups.len() as f64,
        representative: representative.expect("seeds is non-empty"),
    })
}

/// Render a Table III-shaped accuracy table.
pub fn format_accuracy_table(dataset: &str, cells: &[StudyCell]) -> String {
    let mut out = format!("Prediction accuracy on {dataset} (best over run, mean±std)\n");
    out.push_str(&format!("{:<14}", "case"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.engine.to_string()));
    }
    out.push('\n');
    out.push_str(&format!("{:<14}", "RMSE"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.rmse.fmt_paper(4)));
    }
    out.push('\n');
    out.push_str(&format!("{:<14}", "MAE"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.mae.fmt_paper(4)));
    }
    out.push('\n');
    out
}

/// Render a Table IV-shaped training-time table.
pub fn format_time_table(dataset: &str, cells: &[StudyCell]) -> String {
    let mut out = format!("Training time (s) on {dataset} (to best metric, mean±std)\n");
    out.push_str(&format!("{:<14}", "case"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.engine.to_string()));
    }
    out.push('\n');
    out.push_str(&format!("{:<14}", "RMSE-time"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.rmse_time.fmt_paper(2)));
    }
    out.push('\n');
    out.push_str(&format!("{:<14}", "MAE-time"));
    for c in cells {
        out.push_str(&format!("{:>22}", c.mae_time.fmt_paper(2)));
    }
    out.push('\n');
    out
}

/// Write convergence-series CSV (Figs 3–4 data) for a set of cells.
pub fn write_convergence_csv(dir: &Path, dataset: &str, cells: &[StudyCell]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    for c in cells {
        let path = dir.join(format!(
            "convergence_{}_{}.csv",
            dataset.replace('/', "_"),
            c.engine.to_string().to_lowercase().replace('!', "")
        ));
        crate::data::atomic_file::write_atomic(&path, c.representative.history.to_csv().as_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(engine: EngineKind, data: &Dataset) -> TrainConfig {
        TrainConfig::preset(engine, data)
            .threads(2)
            .epochs(3)
            .dim(4)
            .no_early_stop()
    }

    #[test]
    fn resolve_known_keys() {
        assert_eq!(resolve_dataset("small", 1).unwrap().name, "synthetic-small");
        assert!(resolve_dataset("/no/such/file.dat", 1).is_err());
    }

    #[test]
    fn run_cell_aggregates_seeds() {
        let cell = run_cell("small", EngineKind::A2psgd, &[1, 2], &tiny_cfg).unwrap();
        assert_eq!(cell.rmse.n, 2);
        assert!(cell.rmse.mean.is_finite());
        assert!(cell.updates_per_sec > 0.0);
        assert_eq!(cell.representative.history.points().len(), 3);
    }

    #[test]
    fn tables_render() {
        let cell = run_cell("small", EngineKind::Seq, &[3], &tiny_cfg).unwrap();
        let acc = format_accuracy_table("small", std::slice::from_ref(&cell));
        assert!(acc.contains("RMSE") && acc.contains("Seq"));
        let t = format_time_table("small", &[cell]);
        assert!(t.contains("RMSE-time"));
    }

    #[test]
    fn csv_written() {
        let cell = run_cell("small", EngineKind::Seq, &[4], &tiny_cfg).unwrap();
        let dir = std::env::temp_dir().join("a2psgd_csv_test");
        write_convergence_csv(&dir, "small", &[cell]).unwrap();
        let p = dir.join("convergence_small_seq.csv");
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("epoch,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Hyperparameter grid search (paper §IV-A.5: "λ and η are obtained by
//! performing grid search … on the validation set additionally divided on
//! the test set").
//!
//! The training split is re-split into train'/validation; each (η, λ) cell
//! trains on train' and is scored by validation RMSE; the best cell wins.

use crate::data::{split::split_train_test, Dataset};
use crate::engine::{train, EngineKind, TrainConfig};
use crate::optim::Hyper;
use crate::rng::Rng;
use crate::Result;

/// One grid-search cell result.
#[derive(Clone, Copy, Debug)]
pub struct TuneCell {
    /// Learning rate tried.
    pub eta: f32,
    /// Regularization tried.
    pub lam: f32,
    /// Validation RMSE achieved.
    pub rmse: f64,
}

/// Grid-search outcome.
#[derive(Clone, Debug)]
pub struct TuneReport {
    /// All cells, in sweep order.
    pub cells: Vec<TuneCell>,
    /// The winning hyperparameters (γ untouched from the preset).
    pub best: Hyper,
}

/// Sweep η × λ for an engine on a dataset.
///
/// `val_frac` of the training split becomes the validation set. The sweep
/// trains `epochs` epochs per cell (early stop on) and picks the lowest
/// validation RMSE.
pub fn grid_search(
    data: &Dataset,
    engine: EngineKind,
    etas: &[f32],
    lams: &[f32],
    epochs: u32,
    val_frac: f64,
    seed: u64,
) -> Result<TuneReport> {
    assert!(!etas.is_empty() && !lams.is_empty());
    let mut rng = Rng::new(seed ^ 0x7E57);
    let (train_sub, val) = split_train_test(&data.train, val_frac, &mut rng);
    let tune_data = Dataset {
        name: data.name.clone(),
        train: train_sub,
        test: val,
        rating_min: data.rating_min,
        rating_max: data.rating_max,
    };
    let base = TrainConfig::preset(engine, data);
    let mut cells = Vec::with_capacity(etas.len() * lams.len());
    let mut best: Option<(f64, Hyper)> = None;
    for &eta in etas {
        for &lam in lams {
            let hyper = Hyper { eta, lam, gamma: base.hyper.gamma };
            let cfg = base.clone().hyper(hyper).epochs(epochs).seed(seed);
            let report = train(&tune_data, &cfg)?;
            let rmse = report.best_rmse();
            cells.push(TuneCell { eta, lam, rmse });
            if best.map(|(b, _)| rmse < b).unwrap_or(true) {
                best = Some((rmse, hyper));
            }
        }
    }
    Ok(TuneReport { cells, best: best.expect("non-empty grid").1 })
}

/// Render the sweep as an η×λ RMSE matrix.
pub fn format_grid(report: &TuneReport, etas: &[f32], lams: &[f32]) -> String {
    let mut out = String::from("validation RMSE (rows η, cols λ)\n");
    out.push_str(&format!("{:>10}", "η\\λ"));
    for &lam in lams {
        out.push_str(&format!("{lam:>10.0e}"));
    }
    out.push('\n');
    for (i, &eta) in etas.iter().enumerate() {
        out.push_str(&format!("{eta:>10.0e}"));
        for j in 0..lams.len() {
            out.push_str(&format!("{:>10.4}", report.cells[i * lams.len() + j].rmse));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "best: η={:.0e} λ={:.0e}\n",
        report.best.eta, report.best.lam
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn grid_search_picks_a_cell_and_orders_sanely() {
        let data = synthetic::small(31);
        let etas = [5e-3f32, 1e-5];
        let lams = [3e-2f32];
        let r = grid_search(&data, EngineKind::A2psgd, &etas, &lams, 6, 0.2, 1).unwrap();
        assert_eq!(r.cells.len(), 2);
        // η=1e-5 barely moves in 6 epochs — the workable η must win.
        assert_eq!(r.best.eta, 5e-3);
        assert!(r.cells.iter().all(|c| c.rmse.is_finite()));
    }

    #[test]
    fn gamma_preserved_from_preset() {
        let data = synthetic::small(32);
        let r = grid_search(&data, EngineKind::A2psgd, &[2e-3], &[3e-2], 3, 0.2, 1).unwrap();
        assert!(r.best.gamma > 0.0, "A2PSGD preset γ must survive tuning");
    }

    #[test]
    fn format_grid_shows_matrix() {
        let data = synthetic::small(33);
        let etas = [2e-3f32];
        let lams = [1e-2f32, 1e-1];
        let r = grid_search(&data, EngineKind::Seq, &etas, &lams, 3, 0.2, 1).unwrap();
        let s = format_grid(&r, &etas, &lams);
        assert!(s.contains("best:"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }
}

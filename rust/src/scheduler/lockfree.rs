//! A²PSGD's lock-free scheduler (paper Fig. 2, §III-A).
//!
//! No global lock: each row block and column block carries one `AtomicBool`.
//! A scheduling request picks random `(rowBlockId, colBlockId)` and tries to
//! CAS the row lock then the column lock; on any failure it undoes what it
//! took and retries with fresh random indices, up to a bounded budget. The
//! scheduler therefore serves any number of concurrent requests without
//! serializing them — the paper's fix for FPSGD's scalability wall.
//!
//! Lock ordering note: rows are always acquired before columns, and a failed
//! column CAS releases the held row before retrying, so no deadlock is
//! possible (two-phase with back-off, never hold-and-wait).

use super::{BlockScheduler, Claim};
use crate::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Lock-free row/column-atomic scheduler (the A²PSGD scheduler).
pub struct LockFreeScheduler {
    nb: usize,
    row_locks: Vec<AtomicBool>,
    col_locks: Vec<AtomicBool>,
    updates: Vec<AtomicU64>,
    contention: AtomicU64,
    /// Random (i,j) retries per acquire before giving up.
    retry_budget: usize,
}

impl LockFreeScheduler {
    /// Scheduler over an `nb × nb` grid with the default retry budget.
    pub fn new(nb: usize) -> Self {
        Self::with_retry_budget(nb, 4 * nb.max(4))
    }

    /// Scheduler with an explicit retry budget (for experiments).
    pub fn with_retry_budget(nb: usize, retry_budget: usize) -> Self {
        assert!(nb >= 1);
        LockFreeScheduler {
            nb,
            row_locks: (0..nb).map(|_| AtomicBool::new(false)).collect(),
            col_locks: (0..nb).map(|_| AtomicBool::new(false)).collect(),
            updates: (0..nb * nb).map(|_| AtomicU64::new(0)).collect(),
            contention: AtomicU64::new(0),
            retry_budget,
        }
    }

    #[inline]
    fn try_lock(cell: &AtomicBool) -> bool {
        cell.compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }
}

impl BlockScheduler for LockFreeScheduler {
    #[inline]
    fn acquire(&self, rng: &mut Rng) -> Option<Claim> {
        for _ in 0..self.retry_budget {
            let i = rng.gen_index(self.nb);
            let j = rng.gen_index(self.nb);
            if !Self::try_lock(&self.row_locks[i]) {
                self.contention.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if !Self::try_lock(&self.col_locks[j]) {
                // Undo the row so another thread can take it; retry fresh.
                self.row_locks[i].store(false, Ordering::Release);
                self.contention.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            return Some(Claim { i, j });
        }
        None
    }

    #[inline]
    fn release(&self, claim: Claim) {
        self.updates[claim.i * self.nb + claim.j].fetch_add(1, Ordering::Relaxed);
        self.col_locks[claim.j].store(false, Ordering::Release);
        self.row_locks[claim.i].store(false, Ordering::Release);
    }

    fn nblocks(&self) -> usize {
        self.nb
    }

    fn update_counts(&self) -> Vec<u64> {
        self.updates.iter().map(|u| u.load(Ordering::Relaxed)).collect()
    }

    fn contention_events(&self) -> u64 {
        self.contention.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn acquire_release_cycles() {
        let s = LockFreeScheduler::new(4);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let c = s.acquire(&mut rng).expect("empty grid must yield a claim");
            s.release(c);
        }
        let total: u64 = s.update_counts().iter().sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn single_block_grid_is_exclusive() {
        let s = LockFreeScheduler::new(1);
        let mut rng = Rng::new(2);
        let c = s.acquire(&mut rng).unwrap();
        assert!(s.acquire(&mut rng).is_none());
        s.release(c);
        assert!(s.acquire(&mut rng).is_some());
    }

    #[test]
    fn no_lost_releases_under_concurrency() {
        let s = Arc::new(LockFreeScheduler::new(8));
        let per_thread = 5000u64;
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = Arc::clone(&s);
                scope.spawn(move || {
                    let mut rng = Rng::new(t);
                    let mut done = 0;
                    while done < per_thread {
                        if let Some(c) = s.acquire(&mut rng) {
                            s.release(c);
                            done += 1;
                        }
                    }
                });
            }
        });
        let total: u64 = s.update_counts().iter().sum();
        assert_eq!(total, 8 * per_thread);
        // All locks must be free at quiescence.
        let mut rng = Rng::new(99);
        let mut claims = Vec::new();
        for _ in 0..200 {
            if let Some(c) = s.acquire(&mut rng) {
                claims.push(c);
            }
        }
        assert_eq!(claims.len(), 8, "all 8 diagonal slots should be claimable");
        for c in claims {
            s.release(c);
        }
    }

    #[test]
    fn retry_budget_bounds_work() {
        let s = LockFreeScheduler::with_retry_budget(2, 1);
        let mut rng = Rng::new(3);
        // With budget 1 an occupied grid fails fast.
        let a = s.acquire(&mut rng).unwrap();
        let b = s.acquire(&mut rng); // may or may not succeed (random pick)
        let mut misses = 0;
        for _ in 0..50 {
            if s.acquire(&mut rng).is_none() {
                misses += 1;
            } else {
                break;
            }
        }
        let _ = misses;
        s.release(a);
        if let Some(b) = b {
            s.release(b);
        }
        assert!(s.contention_events() > 0);
    }
}

//! A²PSGD's lock-free scheduler (paper Fig. 2, §III-A), optionally
//! *work-aware*.
//!
//! No global lock: each row block and column block carries one `AtomicBool`.
//! A scheduling request picks `(rowBlockId, colBlockId)` and tries to CAS
//! the row lock then the column lock; on any failure it undoes what it took
//! and retries with fresh indices, up to a bounded budget. The scheduler
//! therefore serves any number of concurrent requests without serializing
//! them — the paper's fix for FPSGD's scalability wall.
//!
//! **Selection.** The plain constructor picks `(i, j)` uniformly at random.
//! [`LockFreeScheduler::work_aware`] seeds the scheduler with the grid's
//! per-block instance counts (`BlockGrid::block_nnz`) and biases selection
//! by *remaining work*: a prefix-sum sample over the currently free,
//! non-empty blocks, weighted by each block's processed-instance deficit
//! against the most-processed block. This is FPSGD's "minimal updates"
//! fairness rule, lifted to instance counts and made lock-free — empty
//! blocks are never scheduled (a uniform pick wastes an acquire/release on
//! them), and blocks that have fallen behind in processed instances are
//! preferred, so per-block processed-instance counts stay tight even on
//! skewed grids. Only the *selection* is biased; the CAS protocol and its
//! exclusion invariants are untouched.
//!
//! Tradeoff note: equalizing raw processed-instance counts means a block's
//! per-*instance* visit rate scales with `1/work_b` — on a grid with very
//! unequal block sizes, instances in small blocks are revisited more often
//! per epoch than instances in the hot block. That is the metric the
//! load-balancing study reports (and what the fairness tests assert), and
//! it is benign in the shipped A²PSGD configuration, which pairs this
//! scheduler with the *balanced* partition (Algorithm 1) whose blocks are
//! near-equal. When pairing work-aware selection with a deliberately skewed
//! partition (ablations), prefer the uniform constructor.
//!
//! **Diagnostics.** A failed probe is classified: `contention_events` count
//! probes that lost a race while a free block existed; `starved_probes`
//! count probes made while the grid had no free block at all (every row or
//! every column claimed) — saturation, not contention. A free block exists
//! iff some row *and* some column are unclaimed, since every claim pins
//! exactly one of each.
//!
//! Lock ordering note: rows are always acquired before columns, and a failed
//! column CAS releases the held row before retrying, so no deadlock is
//! possible (two-phase with back-off, never hold-and-wait).

use super::{BlockScheduler, Claim};
use crate::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Lock-free row/column-atomic scheduler (the A²PSGD scheduler).
pub struct LockFreeScheduler {
    nb: usize,
    row_locks: Vec<AtomicBool>,
    col_locks: Vec<AtomicBool>,
    /// Completed block passes per block (row-major).
    passes: Vec<AtomicU64>,
    /// Instances processed per block (row-major).
    processed: Vec<AtomicU64>,
    /// Static per-block work (instances), row-major; empty ⇒ uniform
    /// selection.
    work: Vec<u64>,
    /// Fairness frontier: running max of per-block processed counts,
    /// maintained at release so acquires don't rescan all blocks for it.
    frontier: AtomicU64,
    contention: AtomicU64,
    starved: AtomicU64,
    /// (i,j) probes per acquire before giving up.
    retry_budget: usize,
}

impl LockFreeScheduler {
    /// Uniform-selection scheduler over an `nb × nb` grid with the default
    /// retry budget.
    pub fn new(nb: usize) -> Self {
        Self::with_retry_budget(nb, Self::default_budget(nb))
    }

    /// Uniform-selection scheduler with an explicit retry budget.
    pub fn with_retry_budget(nb: usize, retry_budget: usize) -> Self {
        Self::build(nb, Vec::new(), retry_budget)
    }

    /// Work-aware scheduler: `work` is the grid's row-major per-block
    /// instance counts (`BlockGrid::block_nnz`). Selection is deficit-
    /// weighted over free non-empty blocks (module docs).
    pub fn work_aware(nb: usize, work: &[u64]) -> Self {
        Self::work_aware_with_budget(nb, work, Self::default_budget(nb))
    }

    /// [`LockFreeScheduler::work_aware`] with an explicit retry budget.
    pub fn work_aware_with_budget(nb: usize, work: &[u64], retry_budget: usize) -> Self {
        assert_eq!(work.len(), nb * nb, "work vector must be nb² row-major");
        assert!(
            work.iter().any(|&w| w > 0),
            "work-aware scheduling over an all-empty grid"
        );
        Self::build(nb, work.to_vec(), retry_budget)
    }

    fn default_budget(nb: usize) -> usize {
        4 * nb.max(4)
    }

    fn build(nb: usize, work: Vec<u64>, retry_budget: usize) -> Self {
        assert!(nb >= 1);
        LockFreeScheduler {
            nb,
            row_locks: (0..nb).map(|_| AtomicBool::new(false)).collect(),
            col_locks: (0..nb).map(|_| AtomicBool::new(false)).collect(),
            passes: (0..nb * nb).map(|_| AtomicU64::new(0)).collect(),
            processed: (0..nb * nb).map(|_| AtomicU64::new(0)).collect(),
            work,
            frontier: AtomicU64::new(0),
            contention: AtomicU64::new(0),
            starved: AtomicU64::new(0),
            retry_budget,
        }
    }

    #[inline]
    fn try_lock(cell: &AtomicBool) -> bool {
        cell.compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Classify a failed probe (module docs): contention while a free block
    /// existed, starvation otherwise. O(nb) on the failure path only.
    #[inline]
    fn note_miss(&self) {
        let any_row = self.row_locks.iter().any(|l| !l.load(Ordering::Relaxed));
        let any_col = self.col_locks.iter().any(|l| !l.load(Ordering::Relaxed));
        if any_row && any_col {
            self.contention.fetch_add(1, Ordering::Relaxed);
        } else {
            self.starved.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Deficit weight of a block: distance to the fairness frontier, plus
    /// one so fully caught-up blocks stay selectable.
    #[inline]
    fn deficit(frontier: u64, processed: u64) -> u64 {
        frontier.saturating_sub(processed) + 1
    }

    /// Work-aware candidate pick: prefix-sum sample over free, non-empty
    /// blocks weighted by processed-instance deficit. Returns `None` when no
    /// free non-empty block exists. Concurrent releases may shift weights
    /// between the sizing scan and the sampling scan; the sample then falls
    /// back to the last eligible block seen — a harmless bias for a
    /// randomized heuristic.
    fn pick_weighted(&self, rng: &mut Rng) -> Option<(usize, usize)> {
        let nb = self.nb;
        // The fairness frontier is maintained at release (fetch_max), so
        // the acquire path pays no extra scan for it.
        let frontier = self.frontier.load(Ordering::Relaxed);
        // Scan 1: total deficit weight over claimable blocks.
        let mut total = 0u64;
        for i in 0..nb {
            if self.row_locks[i].load(Ordering::Relaxed) {
                continue;
            }
            for j in 0..nb {
                if self.col_locks[j].load(Ordering::Relaxed) {
                    continue;
                }
                let b = i * nb + j;
                if self.work[b] == 0 {
                    continue;
                }
                let d = Self::deficit(frontier, self.processed[b].load(Ordering::Relaxed));
                total = total.saturating_add(d);
            }
        }
        if total == 0 {
            return None;
        }
        // Scan 2: prefix-sum sample.
        let mut t = rng.gen_range(total);
        let mut last = None;
        for i in 0..nb {
            if self.row_locks[i].load(Ordering::Relaxed) {
                continue;
            }
            for j in 0..nb {
                if self.col_locks[j].load(Ordering::Relaxed) {
                    continue;
                }
                let b = i * nb + j;
                if self.work[b] == 0 {
                    continue;
                }
                let d = Self::deficit(frontier, self.processed[b].load(Ordering::Relaxed));
                last = Some((i, j));
                if t < d {
                    return last;
                }
                t -= d;
            }
        }
        last
    }

    #[inline]
    fn unlock(&self, claim: Claim, instances: u64) {
        let b = claim.i * self.nb + claim.j;
        self.passes[b].fetch_add(1, Ordering::Relaxed);
        let p = self.processed[b].fetch_add(instances, Ordering::Relaxed) + instances;
        self.frontier.fetch_max(p, Ordering::Relaxed);
        self.col_locks[claim.j].store(false, Ordering::Release);
        self.row_locks[claim.i].store(false, Ordering::Release);
    }
}

impl BlockScheduler for LockFreeScheduler {
    #[inline]
    fn acquire(&self, rng: &mut Rng) -> Option<Claim> {
        for _ in 0..self.retry_budget {
            let (i, j) = if self.work.is_empty() {
                (rng.gen_index(self.nb), rng.gen_index(self.nb))
            } else {
                match self.pick_weighted(rng) {
                    Some(p) => p,
                    None => {
                        // No free productive block during the scan.
                        self.starved.fetch_add(1, Ordering::Relaxed);
                        std::hint::spin_loop();
                        continue;
                    }
                }
            };
            if !Self::try_lock(&self.row_locks[i]) {
                self.note_miss();
                continue;
            }
            if !Self::try_lock(&self.col_locks[j]) {
                // Undo the row so another thread can take it; retry fresh.
                self.row_locks[i].store(false, Ordering::Release);
                self.note_miss();
                continue;
            }
            return Some(Claim { i, j });
        }
        None
    }

    #[inline]
    fn release(&self, claim: Claim) {
        // Legacy release: account a whole-block pass. Work-aware callers
        // should prefer `release_processed` with the exact instance count.
        let b = claim.i * self.nb + claim.j;
        let assumed = self.work.get(b).copied().unwrap_or(1).max(1);
        self.unlock(claim, assumed);
    }

    #[inline]
    fn release_processed(&self, claim: Claim, instances: u64) {
        self.unlock(claim, instances);
    }

    fn nblocks(&self) -> usize {
        self.nb
    }

    fn update_counts(&self) -> Vec<u64> {
        self.passes.iter().map(|u| u.load(Ordering::Relaxed)).collect()
    }

    fn instance_counts(&self) -> Vec<u64> {
        self.processed.iter().map(|u| u.load(Ordering::Relaxed)).collect()
    }

    fn contention_events(&self) -> u64 {
        self.contention.load(Ordering::Relaxed)
    }

    fn starved_probes(&self) -> u64 {
        self.starved.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn acquire_release_cycles() {
        let s = LockFreeScheduler::new(4);
        let mut rng = Rng::new(1);
        let iters = crate::testutil::budget(1000, 100);
        for _ in 0..iters {
            let c = s.acquire(&mut rng).expect("empty grid must yield a claim");
            s.release(c);
        }
        let total: u64 = s.update_counts().iter().sum();
        assert_eq!(total, iters as u64);
    }

    #[test]
    fn single_block_grid_is_exclusive() {
        let s = LockFreeScheduler::new(1);
        let mut rng = Rng::new(2);
        let c = s.acquire(&mut rng).unwrap();
        assert!(s.acquire(&mut rng).is_none());
        s.release(c);
        assert!(s.acquire(&mut rng).is_some());
    }

    #[test]
    fn no_lost_releases_under_concurrency() {
        let s = Arc::new(LockFreeScheduler::new(8));
        let per_thread = crate::testutil::budget(5000, 40) as u64;
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = Arc::clone(&s);
                scope.spawn(move || {
                    let mut rng = Rng::new(t);
                    let mut done = 0;
                    while done < per_thread {
                        if let Some(c) = s.acquire(&mut rng) {
                            s.release(c);
                            done += 1;
                        }
                    }
                });
            }
        });
        let total: u64 = s.update_counts().iter().sum();
        assert_eq!(total, 8 * per_thread);
        // All locks must be free at quiescence.
        let mut rng = Rng::new(99);
        let mut claims = Vec::new();
        for _ in 0..200 {
            if let Some(c) = s.acquire(&mut rng) {
                claims.push(c);
            }
        }
        assert_eq!(claims.len(), 8, "all 8 diagonal slots should be claimable");
        for c in claims {
            s.release(c);
        }
    }

    /// Replaces the old `retry_budget_bounds_work` (whose `misses` counter
    /// was dead code): the budget still bounds the probe work, and failed
    /// probes are now *classified* — saturation is not contention.
    #[test]
    fn saturated_grid_counts_starvation_not_contention() {
        let s = LockFreeScheduler::with_retry_budget(1, 3);
        let mut rng = Rng::new(3);
        let c = s.acquire(&mut rng).unwrap();
        // Grid fully claimed: every probe is starvation, never contention.
        for _ in 0..10 {
            assert!(s.acquire(&mut rng).is_none());
        }
        assert_eq!(s.contention_events(), 0, "saturation must not count as contention");
        assert_eq!(s.starved_probes(), 10 * 3, "every budgeted probe starved");
        s.release(c);
        assert!(s.acquire(&mut rng).is_some());
    }

    #[test]
    fn contention_counted_while_free_blocks_exist() {
        let s = LockFreeScheduler::new(2);
        let mut rng = Rng::new(7);
        let held = s.acquire(&mut rng).unwrap();
        // With one claim held on a 2×2 grid a free block always exists, so
        // probes that hit the held row/column are contention, not starvation.
        for _ in 0..200 {
            if let Some(c) = s.acquire(&mut rng) {
                s.release(c);
            }
        }
        assert!(s.contention_events() > 0, "uniform probes must collide with the held claim");
        assert_eq!(s.starved_probes(), 0, "grid was never saturated");
        s.release(held);
    }

    #[test]
    fn work_aware_skips_empty_blocks() {
        // 2×2 grid with work only on the diagonal.
        let s = LockFreeScheduler::work_aware(2, &[10, 0, 0, 30]);
        let mut rng = Rng::new(11);
        for _ in 0..100 {
            let c = s.acquire(&mut rng).expect("free productive blocks exist");
            assert_eq!(c.i, c.j, "only diagonal blocks hold work");
            s.release_processed(c, 1);
        }
        let counts = s.instance_counts();
        assert_eq!(counts[1], 0);
        assert_eq!(counts[2], 0);
        assert_eq!(counts[0] + counts[3], 100);
    }

    #[test]
    fn work_aware_release_processed_feeds_instance_counts() {
        let s = LockFreeScheduler::work_aware(2, &[5, 5, 5, 5]);
        let mut rng = Rng::new(13);
        let mut total = 0u64;
        for k in 0..40u64 {
            let c = s.acquire(&mut rng).unwrap();
            s.release_processed(c, k);
            total += k;
        }
        assert_eq!(s.instance_counts().iter().sum::<u64>(), total);
        assert_eq!(s.update_counts().iter().sum::<u64>(), 40, "passes still tracked");
    }

    #[test]
    fn work_aware_exclusion_preserved() {
        // The CAS protocol must be untouched by biased selection: claims
        // held simultaneously never share a row or column block.
        let work: Vec<u64> = (0..16).map(|b| (b % 5) as u64 * 7).collect();
        let s = LockFreeScheduler::work_aware(4, &work);
        let mut rng = Rng::new(17);
        let mut claims = Vec::new();
        for _ in 0..64 {
            if let Some(c) = s.acquire(&mut rng) {
                claims.push(c);
            }
        }
        let rows: std::collections::HashSet<usize> = claims.iter().map(|c| c.i).collect();
        let cols: std::collections::HashSet<usize> = claims.iter().map(|c| c.j).collect();
        assert_eq!(rows.len(), claims.len(), "duplicate row claim");
        assert_eq!(cols.len(), claims.len(), "duplicate col claim");
        for c in claims {
            s.release(c);
        }
    }

    #[test]
    fn work_aware_concurrent_stress() {
        let work: Vec<u64> = (0..81).map(|b| 1 + (b as u64 * 37) % 500).collect();
        let s = Arc::new(LockFreeScheduler::work_aware(9, &work));
        let per_thread = crate::testutil::budget(2000, 25) as u64;
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = Arc::clone(&s);
                let work = &work;
                scope.spawn(move || {
                    let mut rng = Rng::new(200 + t);
                    let mut done = 0;
                    while done < per_thread {
                        if let Some(c) = s.acquire(&mut rng) {
                            let b = c.i * 9 + c.j;
                            s.release_processed(c, work[b]);
                            done += 1;
                        }
                    }
                });
            }
        });
        assert_eq!(s.update_counts().iter().sum::<u64>(), 8 * per_thread);
        // Quiescent: the full diagonal must be claimable again.
        let mut rng = Rng::new(999);
        let mut claims = Vec::new();
        for _ in 0..200 {
            if let Some(c) = s.acquire(&mut rng) {
                claims.push(c);
            }
        }
        assert_eq!(claims.len(), 9);
        for c in claims {
            s.release(c);
        }
    }
}

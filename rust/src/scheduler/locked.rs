//! FPSGD's scheduler (paper Fig. 1): a single global mutex guards the
//! free-block table. Among free blocks it prefers the least-updated one
//! (FPSGD's "minimal updates" rule), which is good for fairness but the
//! global lock serializes every scheduling request — the scalability
//! bottleneck A²PSGD removes.

use super::{BlockScheduler, Claim};
use crate::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

struct State {
    busy_row: Vec<bool>,
    busy_col: Vec<bool>,
    updates: Vec<u64>,   // completed passes, row-major nb × nb
    processed: Vec<u64>, // processed instances, row-major nb × nb
}

/// Global-lock free-block scheduler (the FPSGD baseline).
pub struct LockedScheduler {
    nb: usize,
    state: Mutex<State>,
    contention: AtomicU64,
}

impl LockedScheduler {
    /// Scheduler over an `nb × nb` grid.
    pub fn new(nb: usize) -> Self {
        assert!(nb >= 1);
        LockedScheduler {
            nb,
            state: Mutex::new(State {
                busy_row: vec![false; nb],
                busy_col: vec![false; nb],
                updates: vec![0; nb * nb],
                processed: vec![0; nb * nb],
            }),
            contention: AtomicU64::new(0),
        }
    }
}

impl BlockScheduler for LockedScheduler {
    fn acquire(&self, rng: &mut Rng) -> Option<Claim> {
        let mut st = self.state.lock().unwrap();
        // Find the free block with the fewest completed updates; break ties
        // randomly so threads don't herd onto one corner.
        let mut best: Option<(u64, Claim)> = None;
        let mut ties = 0u64;
        for i in 0..self.nb {
            if st.busy_row[i] {
                continue;
            }
            for j in 0..self.nb {
                if st.busy_col[j] {
                    continue;
                }
                let u = st.updates[i * self.nb + j];
                match best {
                    Some((b, _)) if u > b => {}
                    Some((b, _)) if u == b => {
                        ties += 1;
                        if rng.gen_range(ties + 1) == 0 {
                            best = Some((u, Claim { i, j }));
                        }
                    }
                    _ => {
                        ties = 0;
                        best = Some((u, Claim { i, j }));
                    }
                }
            }
        }
        match best {
            Some((_, c)) => {
                st.busy_row[c.i] = true;
                st.busy_col[c.j] = true;
                Some(c)
            }
            None => {
                self.contention.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn release(&self, claim: Claim) {
        self.release_processed(claim, 1);
    }

    fn release_processed(&self, claim: Claim, instances: u64) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.busy_row[claim.i] && st.busy_col[claim.j]);
        st.busy_row[claim.i] = false;
        st.busy_col[claim.j] = false;
        st.updates[claim.i * self.nb + claim.j] += 1;
        st.processed[claim.i * self.nb + claim.j] += instances;
    }

    fn nblocks(&self) -> usize {
        self.nb
    }

    fn update_counts(&self) -> Vec<u64> {
        self.state.lock().unwrap().updates.clone()
    }

    fn instance_counts(&self) -> Vec<u64> {
        self.state.lock().unwrap().processed.clone()
    }

    fn contention_events(&self) -> u64 {
        self.contention.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefers_least_updated_block() {
        let s = LockedScheduler::new(2);
        let mut rng = Rng::new(1);
        // Update block (0,0) many times by claiming/releasing when it's the pick.
        for _ in 0..50 {
            let c = s.acquire(&mut rng).unwrap();
            s.release(c);
        }
        let counts = s.update_counts();
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        // Min-update rule keeps the spread tight.
        assert!(max - min <= 1, "counts={counts:?}");
    }

    #[test]
    fn full_grid_returns_none_and_counts_contention() {
        let s = LockedScheduler::new(1);
        let mut rng = Rng::new(2);
        let c = s.acquire(&mut rng).unwrap();
        assert!(s.acquire(&mut rng).is_none());
        assert_eq!(s.contention_events(), 1);
        s.release(c);
    }
}

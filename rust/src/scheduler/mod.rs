//! Free-block schedulers (paper §III-A).
//!
//! A *free block* is a sub-block sharing no row-block or column-block with
//! any block currently being processed. Both schedulers hand free blocks to
//! worker threads; they differ in how scheduling requests synchronize:
//!
//! - [`LockedScheduler`] (FPSGD, Fig. 1): one global mutex guards the whole
//!   free-block table; concurrent requests serialize.
//! - [`LockFreeScheduler`] (A²PSGD, Fig. 2): each row/column block carries
//!   its own atomic; a request CASes the pair `(rowBlockId, colBlockId)`
//!   directly, so requests from different threads proceed concurrently.
//!   [`LockFreeScheduler::work_aware`] additionally biases selection by
//!   remaining per-block work (seeded with the grid's instance counts).
//!
//! Both track per-block passes *and* processed instances — the latter is the
//! honest "curse of the last reducer" metric the load-balancing study
//! reports (a pass over a near-empty block is not a pass over the hot one).

mod locked;
mod lockfree;

pub use locked::LockedScheduler;
pub use lockfree::LockFreeScheduler;

use crate::rng::Rng;

/// A claim on sub-block (i, j); must be released via [`BlockScheduler::release`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Claim {
    /// Row-block index.
    pub i: usize,
    /// Column-block index.
    pub j: usize,
}

/// Common scheduler interface for block-parallel engines.
pub trait BlockScheduler: Send + Sync {
    /// Try to claim a free block. Returns `None` if no block could be
    /// acquired after the scheduler's bounded retry budget (caller may spin).
    fn acquire(&self, rng: &mut Rng) -> Option<Claim>;

    /// Release a claim after processing it.
    fn release(&self, claim: Claim);

    /// Release a claim, recording how many instances the pass processed.
    /// Work-aware schedulers use the count to steer selection and for
    /// instance-level fairness stats; the default discards it.
    fn release_processed(&self, claim: Claim, instances: u64) {
        let _ = instances;
        self.release(claim);
    }

    /// Grid side length (c+1).
    fn nblocks(&self) -> usize;

    /// Per-block completed update-pass counts (row-major), for fairness stats.
    fn update_counts(&self) -> Vec<u64>;

    /// Per-block processed-*instance* counts (row-major). Passes are a poor
    /// fairness measure on skewed grids (a pass over a near-empty block is
    /// not a pass over the hot block); schedulers that track instances
    /// override this. Defaults to [`BlockScheduler::update_counts`].
    fn instance_counts(&self) -> Vec<u64> {
        self.update_counts()
    }

    /// Acquire probes that failed while a free block existed (lost a race).
    fn contention_events(&self) -> u64;

    /// Acquire probes made while no free block existed (grid saturated —
    /// back-pressure, not contention). Defaults to 0 for schedulers that
    /// don't distinguish.
    fn starved_probes(&self) -> u64 {
        0
    }
}

/// Fairness summary: spread of per-block processed-*instance* counts (the
/// "curse of the last reducer" is about work, not visits).
pub fn fairness(sched: &dyn BlockScheduler) -> crate::sparse::stats::CountStats {
    crate::sparse::stats::count_stats(&sched.instance_counts())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    fn schedulers(nb: usize) -> Vec<(&'static str, Arc<dyn BlockScheduler>)> {
        vec![
            ("locked", Arc::new(LockedScheduler::new(nb))),
            ("lockfree", Arc::new(LockFreeScheduler::new(nb))),
        ]
    }

    #[test]
    fn acquire_gives_valid_indices() {
        for (name, s) in schedulers(4) {
            let mut rng = Rng::new(1);
            let c = s.acquire(&mut rng).unwrap_or_else(|| panic!("{name}: no claim"));
            assert!(c.i < 4 && c.j < 4, "{name}");
            s.release(c);
        }
    }

    #[test]
    fn same_row_or_col_never_double_claimed() {
        for (name, s) in schedulers(4) {
            let mut rng = Rng::new(2);
            let mut claims = Vec::new();
            // claim as many as possible
            for _ in 0..64 {
                if let Some(c) = s.acquire(&mut rng) {
                    claims.push(c);
                }
            }
            let rows: HashSet<usize> = claims.iter().map(|c| c.i).collect();
            let cols: HashSet<usize> = claims.iter().map(|c| c.j).collect();
            assert_eq!(rows.len(), claims.len(), "{name}: duplicate row claim");
            assert_eq!(cols.len(), claims.len(), "{name}: duplicate col claim");
            assert!(claims.len() <= 4, "{name}");
            for c in claims {
                s.release(c);
            }
        }
    }

    #[test]
    fn release_makes_block_reacquirable() {
        for (name, s) in schedulers(2) {
            let mut rng = Rng::new(3);
            // Exhaust the 2x2 grid (max 2 concurrent claims).
            let a = s.acquire(&mut rng).unwrap();
            let b = s.acquire(&mut rng).unwrap();
            assert!(s.acquire(&mut rng).is_none(), "{name}: grid should be full");
            s.release(a);
            let c = s.acquire(&mut rng).expect(name);
            s.release(b);
            s.release(c);
        }
    }

    #[test]
    fn update_counts_increment_on_release() {
        for (name, s) in schedulers(3) {
            let mut rng = Rng::new(4);
            let before: u64 = s.update_counts().iter().sum();
            assert_eq!(before, 0, "{name}");
            for _ in 0..10 {
                if let Some(c) = s.acquire(&mut rng) {
                    s.release(c);
                }
            }
            let after: u64 = s.update_counts().iter().sum();
            assert!(after > 0, "{name}");
        }
    }

    /// Stress test: concurrent workers must never overlap rows or columns.
    /// Ownership is verified with an independent atomic table.
    #[test]
    fn concurrent_exclusion_stress() {
        for (name, s) in schedulers(9) {
            let nb = s.nblocks();
            let row_owned: Arc<Vec<AtomicBool>> =
                Arc::new((0..nb).map(|_| AtomicBool::new(false)).collect());
            let col_owned: Arc<Vec<AtomicBool>> =
                Arc::new((0..nb).map(|_| AtomicBool::new(false)).collect());
            let violations = Arc::new(AtomicU64::new(0));
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let s = Arc::clone(&s);
                    let row_owned = Arc::clone(&row_owned);
                    let col_owned = Arc::clone(&col_owned);
                    let violations = Arc::clone(&violations);
                    scope.spawn(move || {
                        let mut rng = Rng::new(100 + t);
                        for _ in 0..crate::testutil::budget(2000, 25) {
                            if let Some(c) = s.acquire(&mut rng) {
                                if row_owned[c.i].swap(true, Ordering::SeqCst) {
                                    violations.fetch_add(1, Ordering::SeqCst);
                                }
                                if col_owned[c.j].swap(true, Ordering::SeqCst) {
                                    violations.fetch_add(1, Ordering::SeqCst);
                                }
                                std::hint::spin_loop();
                                row_owned[c.i].store(false, Ordering::SeqCst);
                                col_owned[c.j].store(false, Ordering::SeqCst);
                                s.release(c);
                            }
                        }
                    });
                }
            });
            assert_eq!(violations.load(Ordering::SeqCst), 0, "{name}: exclusion violated");
        }
    }

    /// Satellite: on a Zipf grid, work-aware selection must yield strictly
    /// lower processed-instance imbalance than uniform random selection.
    #[test]
    fn work_aware_beats_uniform_fairness_on_zipf_grid() {
        use crate::partition::{uniform_bounds, BlockGrid};
        use crate::sparse::CooMatrix;

        // Skewed matrix (popularity ∝ 1/k^2.5) under a *uniform* partition:
        // per-block instance counts follow the node skew.
        let mut rng = crate::rng::Rng::new(21);
        let mut m = CooMatrix::new(240, 240);
        let mut seen = HashSet::new();
        while m.nnz() < 5000 {
            let u = (240.0 * rng.f64().powf(2.5)) as u32;
            let v = (240.0 * rng.f64().powf(2.5)) as u32;
            if seen.insert((u, v)) {
                m.push(u.min(239), v.min(239), 1.0).ok();
            }
        }
        let nb = 6;
        let grid = BlockGrid::new(&m, uniform_bounds(240, nb), uniform_bounds(240, nb));
        let work = grid.block_nnz();
        let total: u64 = work.iter().sum();
        assert!(total > 0);

        // Drive each scheduler through ~5 epochs' worth of instances with a
        // single worker (claims released immediately, so selection bias is
        // the only difference).
        let run = |sched: &dyn BlockScheduler, seed: u64| -> Vec<u64> {
            let mut rng = crate::rng::Rng::new(seed);
            let mut done = 0u64;
            while done < 5 * total {
                let Some(c) = sched.acquire(&mut rng) else { continue };
                let n = work[c.i * nb + c.j];
                sched.release_processed(c, n);
                done += n;
            }
            sched
                .instance_counts()
                .iter()
                .zip(&work)
                .filter(|(_, &w)| w > 0)
                .map(|(&p, _)| p)
                .collect()
        };
        let uniform = LockFreeScheduler::new(nb);
        let aware = LockFreeScheduler::work_aware(nb, &work);
        let iu = crate::sparse::stats::count_stats(&run(&uniform, 31)).imbalance;
        let ia = crate::sparse::stats::count_stats(&run(&aware, 31)).imbalance;
        assert!(
            ia < iu,
            "work-aware imbalance {ia:.3} must beat uniform {iu:.3} on a Zipf grid"
        );
    }

    /// Satellite: the telemetry the obs layer republishes must stay
    /// internally consistent under a multi-threaded Zipf stress run, for
    /// both schedulers — the processed-instance ledger exactly matches what
    /// the workers report, pass counts match successful claims, and every
    /// failed acquire surfaces as contention and/or starvation.
    #[test]
    fn telemetry_consistent_under_multithread_zipf_stress() {
        use crate::partition::{uniform_bounds, BlockGrid};
        use crate::sparse::CooMatrix;

        let mut rng = Rng::new(77);
        let mut m = CooMatrix::new(240, 240);
        let mut seen = HashSet::new();
        while m.nnz() < 5000 {
            let u = (240.0 * rng.f64().powf(2.5)) as u32;
            let v = (240.0 * rng.f64().powf(2.5)) as u32;
            if seen.insert((u, v)) {
                m.push(u.min(239), v.min(239), 1.0).ok();
            }
        }
        let nb = 6;
        let grid = BlockGrid::new(&m, uniform_bounds(240, nb), uniform_bounds(240, nb));
        let work = grid.block_nnz();

        let under_test: Vec<(&str, Arc<dyn BlockScheduler>)> = vec![
            ("locked", Arc::new(LockedScheduler::new(nb))),
            ("lockfree", Arc::new(LockFreeScheduler::work_aware(nb, &work))),
        ];
        for (name, s) in under_test {
            let processed = AtomicU64::new(0);
            let claims = AtomicU64::new(0);
            let failures = AtomicU64::new(0);
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let s = Arc::clone(&s);
                    let (processed, claims, failures) = (&processed, &claims, &failures);
                    let work = &work;
                    scope.spawn(move || {
                        let mut rng = Rng::new(900 + t);
                        for _ in 0..crate::testutil::budget(1500, 25) {
                            match s.acquire(&mut rng) {
                                Some(c) => {
                                    let n = work[c.i * nb + c.j];
                                    s.release_processed(c, n);
                                    processed.fetch_add(n, Ordering::Relaxed);
                                    claims.fetch_add(1, Ordering::Relaxed);
                                }
                                None => {
                                    failures.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    });
                }
            });
            let inst: u64 = s.instance_counts().iter().sum();
            let passes: u64 = s.update_counts().iter().sum();
            assert_eq!(
                inst,
                processed.load(Ordering::Relaxed),
                "{name}: sum of instance_counts must equal instances the workers processed"
            );
            assert_eq!(
                passes,
                claims.load(Ordering::Relaxed),
                "{name}: sum of update_counts must equal successful claims"
            );
            let misses = s.contention_events() + s.starved_probes();
            assert!(
                misses >= failures.load(Ordering::Relaxed),
                "{name}: every failed acquire must be visible as contention or starvation \
                 (misses={misses}, failed acquires={})",
                failures.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn release_processed_default_falls_back_to_release() {
        for (name, s) in schedulers(3) {
            let mut rng = Rng::new(5);
            let c = s.acquire(&mut rng).unwrap_or_else(|| panic!("{name}: no claim"));
            s.release_processed(c, 17);
            assert_eq!(
                s.update_counts().iter().sum::<u64>(),
                1,
                "{name}: release_processed must complete the pass"
            );
            assert_eq!(
                s.instance_counts().iter().sum::<u64>(),
                17,
                "{name}: instances recorded"
            );
        }
    }

    #[test]
    fn property_claims_form_partial_permutation() {
        crate::proptest_lite::check(
            "simultaneous claims are a partial permutation matrix",
            crate::testutil::budget(64, 8) as u32,
            |g| (g.usize_in(1, 12), g.u64(1 << 40)),
            |&(nb, seed)| {
                for (_, s) in schedulers(nb) {
                    let mut rng = Rng::new(seed);
                    let mut claims = Vec::new();
                    for _ in 0..nb * 8 {
                        if let Some(c) = s.acquire(&mut rng) {
                            claims.push(c);
                        }
                    }
                    let rows: HashSet<_> = claims.iter().map(|c| c.i).collect();
                    let cols: HashSet<_> = claims.iter().map(|c| c.j).collect();
                    if rows.len() != claims.len() || cols.len() != claims.len() {
                        return false;
                    }
                }
                true
            },
        );
    }
}

//! Free-block schedulers (paper §III-A).
//!
//! A *free block* is a sub-block sharing no row-block or column-block with
//! any block currently being processed. Both schedulers hand free blocks to
//! worker threads; they differ in how scheduling requests synchronize:
//!
//! - [`LockedScheduler`] (FPSGD, Fig. 1): one global mutex guards the whole
//!   free-block table; concurrent requests serialize.
//! - [`LockFreeScheduler`] (A²PSGD, Fig. 2): each row/column block carries
//!   its own atomic; a request CASes the pair `(rowBlockId, colBlockId)`
//!   directly, so requests from different threads proceed concurrently.
//!
//! Both track per-block update counts — the "curse of the last reducer"
//! metric the load-balancing study reports.

mod locked;
mod lockfree;

pub use locked::LockedScheduler;
pub use lockfree::LockFreeScheduler;

use crate::rng::Rng;

/// A claim on sub-block (i, j); must be released via [`BlockScheduler::release`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Claim {
    /// Row-block index.
    pub i: usize,
    /// Column-block index.
    pub j: usize,
}

/// Common scheduler interface for block-parallel engines.
pub trait BlockScheduler: Send + Sync {
    /// Try to claim a free block. Returns `None` if no block could be
    /// acquired after the scheduler's bounded retry budget (caller may spin).
    fn acquire(&self, rng: &mut Rng) -> Option<Claim>;

    /// Release a claim after processing it.
    fn release(&self, claim: Claim);

    /// Grid side length (c+1).
    fn nblocks(&self) -> usize;

    /// Per-block completed update-pass counts (row-major), for fairness stats.
    fn update_counts(&self) -> Vec<u64>;

    /// Total acquire attempts that failed due to contention (diagnostic).
    fn contention_events(&self) -> u64;
}

/// Fairness summary: spread of per-block update counts.
pub fn fairness(sched: &dyn BlockScheduler) -> crate::sparse::stats::CountStats {
    crate::sparse::stats::count_stats(&sched.update_counts())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    fn schedulers(nb: usize) -> Vec<(&'static str, Arc<dyn BlockScheduler>)> {
        vec![
            ("locked", Arc::new(LockedScheduler::new(nb))),
            ("lockfree", Arc::new(LockFreeScheduler::new(nb))),
        ]
    }

    #[test]
    fn acquire_gives_valid_indices() {
        for (name, s) in schedulers(4) {
            let mut rng = Rng::new(1);
            let c = s.acquire(&mut rng).unwrap_or_else(|| panic!("{name}: no claim"));
            assert!(c.i < 4 && c.j < 4, "{name}");
            s.release(c);
        }
    }

    #[test]
    fn same_row_or_col_never_double_claimed() {
        for (name, s) in schedulers(4) {
            let mut rng = Rng::new(2);
            let mut claims = Vec::new();
            // claim as many as possible
            for _ in 0..64 {
                if let Some(c) = s.acquire(&mut rng) {
                    claims.push(c);
                }
            }
            let rows: HashSet<usize> = claims.iter().map(|c| c.i).collect();
            let cols: HashSet<usize> = claims.iter().map(|c| c.j).collect();
            assert_eq!(rows.len(), claims.len(), "{name}: duplicate row claim");
            assert_eq!(cols.len(), claims.len(), "{name}: duplicate col claim");
            assert!(claims.len() <= 4, "{name}");
            for c in claims {
                s.release(c);
            }
        }
    }

    #[test]
    fn release_makes_block_reacquirable() {
        for (name, s) in schedulers(2) {
            let mut rng = Rng::new(3);
            // Exhaust the 2x2 grid (max 2 concurrent claims).
            let a = s.acquire(&mut rng).unwrap();
            let b = s.acquire(&mut rng).unwrap();
            assert!(s.acquire(&mut rng).is_none(), "{name}: grid should be full");
            s.release(a);
            let c = s.acquire(&mut rng).expect(name);
            s.release(b);
            s.release(c);
        }
    }

    #[test]
    fn update_counts_increment_on_release() {
        for (name, s) in schedulers(3) {
            let mut rng = Rng::new(4);
            let before: u64 = s.update_counts().iter().sum();
            assert_eq!(before, 0, "{name}");
            for _ in 0..10 {
                if let Some(c) = s.acquire(&mut rng) {
                    s.release(c);
                }
            }
            let after: u64 = s.update_counts().iter().sum();
            assert!(after > 0, "{name}");
        }
    }

    /// Stress test: concurrent workers must never overlap rows or columns.
    /// Ownership is verified with an independent atomic table.
    #[test]
    fn concurrent_exclusion_stress() {
        for (name, s) in schedulers(9) {
            let nb = s.nblocks();
            let row_owned: Arc<Vec<AtomicBool>> =
                Arc::new((0..nb).map(|_| AtomicBool::new(false)).collect());
            let col_owned: Arc<Vec<AtomicBool>> =
                Arc::new((0..nb).map(|_| AtomicBool::new(false)).collect());
            let violations = Arc::new(AtomicU64::new(0));
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let s = Arc::clone(&s);
                    let row_owned = Arc::clone(&row_owned);
                    let col_owned = Arc::clone(&col_owned);
                    let violations = Arc::clone(&violations);
                    scope.spawn(move || {
                        let mut rng = Rng::new(100 + t);
                        for _ in 0..2000 {
                            if let Some(c) = s.acquire(&mut rng) {
                                if row_owned[c.i].swap(true, Ordering::SeqCst) {
                                    violations.fetch_add(1, Ordering::SeqCst);
                                }
                                if col_owned[c.j].swap(true, Ordering::SeqCst) {
                                    violations.fetch_add(1, Ordering::SeqCst);
                                }
                                std::hint::spin_loop();
                                row_owned[c.i].store(false, Ordering::SeqCst);
                                col_owned[c.j].store(false, Ordering::SeqCst);
                                s.release(c);
                            }
                        }
                    });
                }
            });
            assert_eq!(violations.load(Ordering::SeqCst), 0, "{name}: exclusion violated");
        }
    }

    #[test]
    fn property_claims_form_partial_permutation() {
        crate::proptest_lite::check(
            "simultaneous claims are a partial permutation matrix",
            64,
            |g| (g.usize_in(1, 12), g.u64(1 << 40)),
            |&(nb, seed)| {
                for (_, s) in schedulers(nb) {
                    let mut rng = Rng::new(seed);
                    let mut claims = Vec::new();
                    for _ in 0..nb * 8 {
                        if let Some(c) = s.acquire(&mut rng) {
                            claims.push(c);
                        }
                    }
                    let rows: HashSet<_> = claims.iter().map(|c| c.i).collect();
                    let cols: HashSet<_> = claims.iter().map(|c| c.j).collect();
                    if rows.len() != claims.len() || cols.len() != claims.len() {
                        return false;
                    }
                }
                true
            },
        );
    }
}

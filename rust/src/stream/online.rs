//! The online trainer: sliding-window incremental NAG over the lock-free
//! block scheduler, with fold-in for new nodes and periodic snapshot
//! publication.
//!
//! Each ingested micro-batch is processed in four steps:
//!
//! 1. **Resolve** external ids through the [`IdMap`], growing the factor
//!    matrices for never-before-seen users/items.
//! 2. **Route** every `holdout_every`-th event to the rolling holdout ring
//!    (the online test set); the rest enter the sliding window.
//! 3. **Update**: fold in new nodes (one-sided NAG on their rows only),
//!    then run `passes` sweeps of the full update rule over the window —
//!    multi-threaded through a balanced block grid and the A²PSGD lock-free
//!    scheduler, exactly like the offline engine but scoped to recent
//!    events.
//! 4. **Publish** every `publish_every` batches: clone the working factors
//!    into the [`SnapshotStore`], where the serving path picks them up at
//!    its next batch boundary with zero downtime.
//!
//! The trainer owns its working copy of the factors (the publisher-side
//! buffer of the double-buffering scheme); readers only ever see published
//! immutable snapshots.

use super::foldin::{fold_in_item, fold_in_user};
use super::source::{EventSource, MicroBatch};
use super::StreamConfig;
use crate::coordinator::service::ExclusionSet;
use crate::data::loader::IdMap;
use crate::metrics::RollingHoldout;
use crate::model::{Factors, SharedFactors, SnapshotStore};
use crate::optim::kernel::KernelSet;
use crate::partition::{build_grid, PartitionKind};
use crate::runtime::pool::{Backoff, WorkerPool};
use crate::scheduler::{BlockScheduler, LockFreeScheduler};
use crate::sparse::{CooMatrix, Entry, SweepLanes};
use crate::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Counters accumulated over the life of an [`OnlineTrainer`].
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineStats {
    /// Micro-batches ingested.
    pub batches: u64,
    /// Events ingested (trained + held out).
    pub events: u64,
    /// Events that entered the sliding window.
    pub trained_events: u64,
    /// Events routed to the rolling holdout ring.
    pub holdout_events: u64,
    /// Users folded in (never seen before the stream).
    pub new_users: u64,
    /// Items folded in.
    pub new_items: u64,
    /// Per-instance window updates executed.
    pub updates: u64,
    /// Snapshots published.
    pub publishes: u64,
}

/// Streaming trainer; see the module docs for the processing pipeline.
pub struct OnlineTrainer {
    cfg: StreamConfig,
    factors: Factors,
    map: IdMap,
    window: VecDeque<Entry>,
    holdout: RollingHoldout,
    store: Arc<SnapshotStore>,
    rating: (f32, f32),
    init_scale: f32,
    rng: crate::rng::Rng,
    stats: OnlineStats,
    event_seq: u64,
    exclusions: Option<Arc<ExclusionSet>>,
    kernels: KernelSet,
    pool: WorkerPool,
}

impl OnlineTrainer {
    /// Wrap trained `factors` (the working copy) and their id `map` for
    /// online updates publishing into `store`. `rating` is the clamp range
    /// used for holdout evaluation and new-row init scaling.
    pub fn new(
        factors: Factors,
        map: IdMap,
        cfg: StreamConfig,
        store: Arc<SnapshotStore>,
        rating: (f32, f32),
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            factors.nrows() == map.n_users() && factors.ncols() == map.n_items(),
            "factors {}x{} disagree with id map {}x{}",
            factors.nrows(),
            factors.ncols(),
            map.n_users(),
            map.n_items()
        );
        let midpoint = 0.5 * (rating.0 + rating.1);
        let init_scale = Factors::default_scale(midpoint as f64, factors.d());
        let rng = crate::rng::Rng::new(cfg.seed ^ 0x0A71E5);
        let kernels = KernelSet::select(factors.d(), cfg.kernel);
        Ok(OnlineTrainer {
            holdout: RollingHoldout::new(cfg.holdout_cap),
            window: VecDeque::with_capacity(cfg.window.min(1 << 16)),
            pool: WorkerPool::new(cfg.threads),
            cfg,
            factors,
            map,
            store,
            rating,
            init_scale,
            rng,
            stats: OnlineStats::default(),
            event_seq: 0,
            exclusions: None,
            kernels,
        })
    }

    /// Share the serving-side top-k exclusion set: every streamed
    /// interaction is recorded there, so a user is never recommended items
    /// they consumed on the stream (including right after fold-in).
    pub fn share_exclusions(&mut self, ex: Arc<ExclusionSet>) {
        self.exclusions = Some(ex);
    }

    /// Ingest one micro-batch: resolve, route, fold in, update, publish.
    pub fn ingest(&mut self, batch: &MicroBatch) {
        let _span = crate::obs::span("ingest", "stream");
        // Mirror this batch's stat deltas onto the obs registry afterwards —
        // `stats` stays the source of truth, obs gets the same numbers.
        let obs_before = crate::obs::metrics_enabled().then_some(self.stats);
        self.stats.batches += 1;
        // Per-batch fold-in observation lists, keyed by *new* dense ids
        // (BTreeMap for a deterministic fold-in order).
        let mut new_users: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        let mut new_items: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        for ev in &batch.events {
            self.stats.events += 1;
            let (du, fresh_u) = self.map.intern_user(ev.u);
            if fresh_u {
                self.factors.grow_rows(1, self.init_scale, &mut self.rng);
                self.stats.new_users += 1;
                new_users.insert(du, Vec::new());
            }
            let (dv, fresh_v) = self.map.intern_item(ev.v);
            if fresh_v {
                self.factors.grow_cols(1, self.init_scale, &mut self.rng);
                self.stats.new_items += 1;
                new_items.insert(dv, Vec::new());
            }
            self.event_seq += 1;
            let entry = Entry { u: du, v: dv, r: ev.r };
            if self.event_seq % self.cfg.holdout_every == 0 {
                self.holdout.push(entry);
                self.stats.holdout_events += 1;
                continue;
            }
            self.stats.trained_events += 1;
            if let Some(obs) = new_users.get_mut(&du) {
                obs.push((dv, ev.r));
            }
            if let Some(obs) = new_items.get_mut(&dv) {
                obs.push((du, ev.r));
            }
            if self.window.len() == self.cfg.window {
                self.window.pop_front();
            }
            self.window.push_back(entry);
        }
        for (u, obs) in &new_users {
            if !obs.is_empty() {
                fold_in_user(&mut self.factors, *u, obs, &self.cfg.hyper, self.cfg.foldin_steps);
            }
        }
        for (v, obs) in &new_items {
            if !obs.is_empty() {
                fold_in_item(&mut self.factors, *v, obs, &self.cfg.hyper, self.cfg.foldin_steps);
            }
        }
        if let Some(ex) = &self.exclusions {
            // Everything in the batch was consumed by its user — held-out
            // events included — so none of it should be recommended back.
            ex.extend(batch.events.iter().filter_map(|e| {
                Some((self.map.user(e.u)?, self.map.item(e.v)?))
            }));
        }
        self.window_pass();
        if self.stats.batches % self.cfg.publish_every == 0 {
            self.publish();
        }
        if let Some(before) = obs_before {
            crate::obs::add(crate::obs::Ctr::StreamBatches, 1);
            crate::obs::add(crate::obs::Ctr::FoldinUsers, self.stats.new_users - before.new_users);
            crate::obs::add(crate::obs::Ctr::FoldinItems, self.stats.new_items - before.new_items);
            crate::obs::add(crate::obs::Ctr::StreamUpdates, self.stats.updates - before.updates);
        }
    }

    /// Drain an event source to exhaustion, then publish the final state.
    pub fn run(&mut self, src: &mut dyn EventSource) -> OnlineStats {
        while let Some(batch) = src.next_batch(self.cfg.batch) {
            self.ingest(&batch);
        }
        self.publish();
        self.stats
    }

    /// Clone the working factors into the snapshot store; returns the new
    /// version.
    pub fn publish(&mut self) -> u64 {
        self.stats.publishes += 1;
        crate::obs::add(crate::obs::Ctr::SnapshotPublishes, 1);
        self.store.publish(self.factors.clone())
    }

    /// Below this many window entries the serial path wins: the parallel
    /// path pays a window copy and a grid build per ingested batch (the
    /// worker threads themselves are persistent — parked in the pool
    /// between batches), which only amortizes once the
    /// O(window · passes · D) update work dwarfs it.
    const PARALLEL_WINDOW_MIN: usize = 2048;

    /// `passes` sweeps of the update rule over the sliding window.
    fn window_pass(&mut self) {
        let passes = self.cfg.passes;
        if passes == 0 || self.window.is_empty() {
            return;
        }
        if self.cfg.threads == 1 || self.window.len() < Self::PARALLEL_WINDOW_MIN {
            // Serial fast path: no grid build, deterministic order.
            let h = self.cfg.hyper;
            let rule = self.cfg.rule;
            let kernels = self.kernels;
            let d = self.factors.d();
            let f = &mut self.factors;
            for _ in 0..passes {
                for e in &self.window {
                    let (ui, vi) = (e.u as usize * d, e.v as usize * d);
                    let (m, n, phi, psi) = (&mut f.m, &mut f.n, &mut f.phi, &mut f.psi);
                    kernels.apply(
                        rule,
                        &mut m[ui..ui + d],
                        &mut n[vi..vi + d],
                        &mut phi[ui..ui + d],
                        &mut psi[vi..vi + d],
                        e.r,
                        &h,
                    );
                }
            }
            self.stats.updates += self.window.len() as u64 * passes as u64;
            return;
        }
        // Parallel path: balanced grid over the window + work-aware
        // lock-free scheduler, the same machinery as the offline A²PSGD
        // engine (block-local CSR lanes, deficit-biased block selection),
        // run on the trainer's persistent worker pool.
        let entries: Vec<Entry> = self.window.iter().copied().collect();
        let coo = CooMatrix::from_entries(self.factors.nrows(), self.factors.ncols(), entries)
            .expect("window entries are dense-id validated");
        let grid = build_grid(&coo, PartitionKind::Balanced, self.cfg.threads);
        let sched = LockFreeScheduler::work_aware(grid.nblocks(), &grid.block_nnz());
        let quota = coo.nnz() as u64 * passes as u64;
        let hyper = self.cfg.hyper;
        let rule = self.cfg.rule;
        let kernels = self.kernels;
        let placeholder = Factors::from_parts(0, 0, self.factors.d(), vec![], vec![], vec![], vec![])
            .expect("placeholder factors");
        let shared = SharedFactors::new(std::mem::replace(&mut self.factors, placeholder));
        let done = AtomicU64::new(0);
        let base = self.rng.fork(self.stats.batches);
        self.pool.run(|t| {
            let mut rng = base.clone().fork(t as u64);
            let mut backoff = Backoff::new();
            loop {
                if done.load(Ordering::Relaxed) >= quota {
                    return;
                }
                let Some(claim) = sched.acquire(&mut rng) else {
                    backoff.wait();
                    continue;
                };
                backoff.reset();
                let n = grid.block(claim.i, claim.j).sweep(|u, v, r| {
                    // SAFETY: the scheduler guarantees no concurrent
                    // claim shares this row or column block, so the rows
                    // touched here are exclusively ours (the same
                    // contract as the offline block engines).
                    let (mu, nv, phiu, psiv) = unsafe { shared.rows_mut(u, v) };
                    kernels.apply(rule, mu, nv, phiu, psiv, r, &hyper);
                });
                done.fetch_add(n, Ordering::Relaxed);
                sched.release_processed(claim, n);
            }
        });
        self.factors = shared.into_inner();
        self.stats.updates += done.load(Ordering::Relaxed);
    }

    /// Rolling-holdout RMSE under the current *working* factors.
    pub fn holdout_rmse(&self) -> Option<f64> {
        self.holdout.rmse(&self.factors, self.rating.0, self.rating.1)
    }

    /// The rolling holdout ring (evaluate older snapshots against it).
    pub fn holdout(&self) -> &RollingHoldout {
        &self.holdout
    }

    /// Current working factors (publisher-side buffer).
    pub fn factors(&self) -> &Factors {
        &self.factors
    }

    /// The external↔dense id map (grown by the stream).
    pub fn map(&self) -> &IdMap {
        &self.map
    }

    /// Counters so far.
    pub fn stats(&self) -> &OnlineStats {
        &self.stats
    }

    /// The snapshot store this trainer publishes into.
    pub fn store(&self) -> &Arc<SnapshotStore> {
        &self.store
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Hyper;
    use crate::rng::Rng;
    use crate::stream::source::{Event, ReplaySource};

    fn cfg(threads: usize) -> StreamConfig {
        // Window above PARALLEL_WINDOW_MIN so the threads=4 case exercises
        // the grid/scheduler path once enough events have streamed.
        StreamConfig::preset("synthetic-small")
            .batch(64)
            .window(4096)
            .publish_every(2)
            .threads(threads)
            .hyper(Hyper::nag(0.005, 0.01, 0.9))
            .seed(7)
    }

    /// Ground-truth factors and a stream of exact interactions from them.
    fn truth_stream(nrows: u32, ncols: u32, n_events: usize, seed: u64) -> (Factors, Vec<Event>) {
        let mut rng = Rng::new(seed);
        let truth = Factors::init(nrows, ncols, 4, Factors::default_scale(3.0, 4), &mut rng);
        let events = (0..n_events)
            .map(|i| {
                let u = rng.gen_index(nrows as usize) as u32;
                let v = rng.gen_index(ncols as usize) as u32;
                Event {
                    t: i as u64,
                    u: u as u64,
                    v: v as u64,
                    r: truth.predict(u, v).clamp(1.0, 5.0),
                }
            })
            .collect();
        (truth, events)
    }

    fn fresh_trainer(nrows: u32, ncols: u32, threads: usize) -> OnlineTrainer {
        let mut rng = Rng::new(99);
        let factors =
            Factors::init(nrows, ncols, 4, Factors::default_scale(3.0, 4), &mut rng);
        let store = Arc::new(SnapshotStore::new(factors.clone()));
        OnlineTrainer::new(
            factors,
            IdMap::identity(nrows, ncols),
            cfg(threads),
            store,
            (1.0, 5.0),
        )
        .unwrap()
    }

    #[test]
    fn new_rejects_map_shape_mismatch() {
        let mut rng = Rng::new(1);
        let f = Factors::init(4, 4, 2, 0.3, &mut rng);
        let store = Arc::new(SnapshotStore::new(f.clone()));
        let r = OnlineTrainer::new(f, IdMap::identity(3, 4), cfg(1), store, (1.0, 5.0));
        assert!(r.is_err());
    }

    #[test]
    fn ingest_grows_factors_for_unseen_nodes() {
        let mut t = fresh_trainer(4, 4, 1);
        let batch = MicroBatch {
            seq: 0,
            events: vec![
                Event { t: 0, u: 100, v: 0, r: 4.0 }, // new user
                Event { t: 1, u: 100, v: 200, r: 3.0 }, // new item
                Event { t: 2, u: 0, v: 0, r: 2.0 },   // known pair
            ],
        };
        t.ingest(&batch);
        assert_eq!(t.factors().nrows(), 5);
        assert_eq!(t.factors().ncols(), 5);
        assert_eq!(t.map().user(100), Some(4));
        assert_eq!(t.map().item(200), Some(4));
        assert_eq!(t.stats().new_users, 1);
        assert_eq!(t.stats().new_items, 1);
        assert_eq!(t.stats().events, 3);
        assert!(t.stats().updates > 0);
    }

    #[test]
    fn holdout_routing_and_window_capacity() {
        let mut t = fresh_trainer(8, 8, 1);
        t.cfg.holdout_every = 2; // every 2nd event held out
        t.cfg.window = 4;
        let events: Vec<Event> = (0..20)
            .map(|i| Event { t: i, u: (i % 8), v: ((i * 3) % 8), r: 3.0 })
            .collect();
        t.ingest(&MicroBatch { seq: 0, events });
        assert_eq!(t.stats().holdout_events, 10);
        assert_eq!(t.stats().trained_events, 10);
        assert_eq!(t.holdout().len(), 10);
        assert_eq!(t.window.len(), 4, "window must stay capacity-bounded");
    }

    #[test]
    fn publish_cadence_bumps_store_version() {
        let mut t = fresh_trainer(4, 4, 1);
        let store = Arc::clone(t.store());
        assert_eq!(store.version(), 1);
        let mk = |seq| MicroBatch {
            seq,
            events: vec![Event { t: seq, u: 0, v: 1, r: 3.0 }],
        };
        t.ingest(&mk(0));
        assert_eq!(store.version(), 1, "publish_every=2: no publish after batch 1");
        t.ingest(&mk(1));
        assert_eq!(store.version(), 2, "published after batch 2");
        assert_eq!(t.stats().publishes, 1);
    }

    #[test]
    fn streaming_improves_holdout_rmse() {
        for threads in [1usize, 4] {
            let (_, events) = truth_stream(24, 16, 4000, 5);
            let mut t = fresh_trainer(24, 16, threads);
            let initial = t.store().load();
            let mut src = ReplaySource::new(events);
            let stats = t.run(&mut src);
            assert!(stats.holdout_events > 50);
            let before = t
                .holdout()
                .rmse(initial.factors(), 1.0, 5.0)
                .expect("holdout non-empty");
            let after = t.holdout_rmse().expect("holdout non-empty");
            assert!(
                after < before,
                "threads={threads}: rmse must improve, {before:.4} → {after:.4}"
            );
            assert!(t.store().version() > 1);
        }
    }
}

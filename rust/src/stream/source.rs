//! Event ingestion: timestamped interaction events, bounded micro-batches,
//! and the sources that produce them.
//!
//! Three sources cover the production and benchmarking stories:
//!
//! - [`ChannelSource`] — a live source fed through an [`EventSender`] from
//!   any number of producer threads; `next_batch` drains up to the batch
//!   bound or a wait deadline, so ingestion latency is bounded even under
//!   trickle traffic.
//! - [`ReplaySource`] — replays a recorded interaction log (e.g. any
//!   existing [`crate::data::Dataset`]'s entries) in timestamp order as a
//!   simulated live stream, which is what the benchmarks and the
//!   `online_serving` example drive.
//! - [`ShardReplaySource`] — replays a packed `.a2ps` shard directory
//!   ([`crate::data::shard`]) without materializing it: records stream
//!   through a bounded chunk buffer, dense ids translate back to external
//!   ids through the directory's embedded id map. This is how a stream
//!   warm-replay runs over datasets larger than RAM.

use crate::data::loader::IdMap;
use crate::data::shard::{self, Manifest, ShardReader};
use crate::sparse::{CooMatrix, Entry};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One timestamped interaction observed on the stream. Node ids are
/// *external* (application key space); the online trainer resolves them to
/// dense ids through an [`IdMap`], growing the factors for unseen nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Event time (any monotone unit — replay uses the log position).
    pub t: u64,
    /// External user id.
    pub u: u64,
    /// External item id.
    pub v: u64,
    /// Interaction weight / rating.
    pub r: f32,
}

/// A bounded micro-batch of events, in arrival order.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Monotone batch sequence number (0-based per source).
    pub seq: u64,
    /// The events (non-empty; length ≤ the requested bound).
    pub events: Vec<Event>,
}

/// Anything that yields bounded micro-batches of interaction events.
pub trait EventSource {
    /// Next micro-batch of at most `max_events` (≥ 1) events, or `None`
    /// when the stream is exhausted. Never returns an empty batch.
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch>;
}

/// Replay a recorded event log as a simulated live stream.
#[derive(Clone, Debug)]
pub struct ReplaySource {
    events: Vec<Event>,
    pos: usize,
    seq: u64,
}

impl ReplaySource {
    /// Replay `events` in timestamp order (stable for equal timestamps).
    pub fn new(mut events: Vec<Event>) -> Self {
        events.sort_by_key(|e| e.t);
        ReplaySource { events, pos: 0, seq: 0 }
    }

    /// Replay a dense COO matrix; external ids are the dense ids and the
    /// timestamp is the entry's position in the log.
    pub fn from_coo(coo: &CooMatrix) -> Self {
        Self::from_entries(coo.entries(), None)
    }

    /// Replay dense entries, optionally translating back to external ids
    /// through `map` (entries whose dense ids the map does not know keep
    /// their dense id as the external id).
    pub fn from_entries(entries: &[Entry], map: Option<&IdMap>) -> Self {
        let events = entries
            .iter()
            .enumerate()
            .map(|(i, e)| Event {
                t: i as u64,
                u: map
                    .and_then(|m| m.external_user(e.u))
                    .unwrap_or(e.u as u64),
                v: map
                    .and_then(|m| m.external_item(e.v))
                    .unwrap_or(e.v as u64),
                r: e.r,
            })
            .collect();
        ReplaySource { events, pos: 0, seq: 0 }
    }

    /// Events not yet replayed.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.pos
    }
}

impl EventSource for ReplaySource {
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch> {
        assert!(max_events >= 1);
        if self.pos >= self.events.len() {
            return None;
        }
        let end = (self.pos + max_events).min(self.events.len());
        let events = self.events[self.pos..end].to_vec();
        self.pos = end;
        let seq = self.seq;
        self.seq += 1;
        Some(MicroBatch { seq, events })
    }
}

/// Replays a packed shard directory as a simulated live stream without
/// materializing it (see the module docs). Event timestamps are the global
/// record index (canonical shard order); ids are external via the embedded
/// [`IdMap`], so the online trainer folds them in exactly as it would live
/// traffic.
pub struct ShardReplaySource {
    dir: PathBuf,
    manifest: Manifest,
    next_shard: usize,
    reader: Option<ShardReader>,
    map: IdMap,
    buf: Vec<Entry>,
    pos: usize,
    t: u64,
    seq: u64,
    chunk: usize,
    remaining: u64,
    error: Option<anyhow::Error>,
}

impl ShardReplaySource {
    /// Open a shard directory for replay (default chunk size).
    pub fn open(dir: &Path) -> crate::Result<Self> {
        Self::with_chunk(dir, shard::DEFAULT_CHUNK)
    }

    /// Open with an explicit records-per-chunk buffer bound.
    pub fn with_chunk(dir: &Path, chunk: usize) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let map = shard::load_idmap(dir)?;
        Ok(ShardReplaySource {
            dir: dir.to_path_buf(),
            remaining: manifest.nnz,
            manifest,
            next_shard: 0,
            reader: None,
            map,
            buf: Vec::new(),
            pos: 0,
            t: 0,
            seq: 0,
            chunk: chunk.max(1),
            error: None,
        })
    }

    /// Skip the first `k` shards entirely (builder style). Because shards
    /// are contiguous dense-row ranges, this replays exactly the users of
    /// the row suffix — the cold side of an out-of-core warm/cold split.
    /// Timestamps stay the *global* canonical record index, so a skipped
    /// replay is positionally identical to the tail of a full replay.
    pub fn skip_shards(mut self, k: usize) -> Self {
        let k = k.min(self.manifest.shards.len());
        let skipped: u64 = self.manifest.shards[..k].iter().map(|m| m.nnz).sum();
        self.next_shard = k;
        self.reader = None;
        self.buf.clear();
        self.pos = 0;
        self.t = skipped;
        self.remaining = self.manifest.nnz - skipped;
        self
    }

    /// Events not yet replayed.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// The IO/corruption error that ended the stream early, if any
    /// ([`EventSource::next_batch`] has no error channel; a failing stream
    /// reports exhaustion and parks the error here).
    pub fn error(&self) -> Option<&anyhow::Error> {
        self.error.as_ref()
    }

    /// Ensure the chunk buffer has an unconsumed record; false ⇒ exhausted.
    fn refill(&mut self) -> crate::Result<bool> {
        loop {
            if self.pos < self.buf.len() {
                return Ok(true);
            }
            if let Some(reader) = self.reader.as_mut() {
                let n = reader.next_chunk(&mut self.buf, self.chunk)?;
                self.pos = 0;
                if n > 0 {
                    return Ok(true);
                }
                self.reader = None;
            }
            if self.next_shard >= self.manifest.shards.len() {
                return Ok(false);
            }
            let meta = &self.manifest.shards[self.next_shard];
            self.next_shard += 1;
            // Manifest cross-check included — a swapped-in foreign shard
            // fails here instead of silently skewing the replay.
            self.reader = Some(shard::open_checked(&self.dir, &self.manifest, meta)?);
        }
    }
}

impl EventSource for ShardReplaySource {
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch> {
        assert!(max_events >= 1);
        if self.error.is_some() {
            return None;
        }
        let mut events = Vec::with_capacity(max_events.min(1024));
        while events.len() < max_events {
            match self.refill() {
                Ok(true) => {}
                Ok(false) => break,
                Err(e) => {
                    eprintln!("shard replay aborted: {e:#}");
                    self.error = Some(e);
                    break;
                }
            }
            let e = self.buf[self.pos];
            self.pos += 1;
            self.remaining = self.remaining.saturating_sub(1);
            events.push(Event {
                t: self.t,
                u: self.map.external_user(e.u).unwrap_or(e.u as u64),
                v: self.map.external_item(e.v).unwrap_or(e.v as u64),
                r: e.r,
            });
            self.t += 1;
        }
        if events.is_empty() {
            return None;
        }
        let seq = self.seq;
        self.seq += 1;
        Some(MicroBatch { seq, events })
    }
}

/// Producer handle for a [`ChannelSource`]; cloneable across threads.
#[derive(Clone)]
pub struct EventSender {
    tx: mpsc::Sender<Event>,
}

impl EventSender {
    /// Enqueue one event; fails once the source has been dropped.
    pub fn send(&self, e: Event) -> crate::Result<()> {
        self.tx.send(e).map_err(|_| anyhow::anyhow!("event source closed"))
    }
}

/// A live event source fed through a channel.
pub struct ChannelSource {
    rx: mpsc::Receiver<Event>,
    max_wait: Duration,
    seq: u64,
}

impl ChannelSource {
    /// Create the source plus its producer handle. `max_wait` bounds how
    /// long a partially filled micro-batch waits for more events.
    pub fn new(max_wait: Duration) -> (EventSender, ChannelSource) {
        let (tx, rx) = mpsc::channel();
        (EventSender { tx }, ChannelSource { rx, max_wait, seq: 0 })
    }
}

impl EventSource for ChannelSource {
    /// Blocks for the first event, then drains until `max_events` or the
    /// `max_wait` deadline. Returns `None` once every sender has dropped
    /// and the queue is empty.
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch> {
        assert!(max_events >= 1);
        let first = self.rx.recv().ok()?;
        let mut events = Vec::with_capacity(max_events.min(1024));
        events.push(first);
        let deadline = Instant::now() + self.max_wait;
        while events.len() < max_events {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(e) => events.push(e),
                Err(_) => break, // timeout or disconnected — ship what we have
            }
        }
        let seq = self.seq;
        self.seq += 1;
        Some(MicroBatch { seq, events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, u: u64, v: u64, r: f32) -> Event {
        Event { t, u, v, r }
    }

    #[test]
    fn replay_batches_are_bounded_and_ordered() {
        let events = vec![ev(3, 0, 0, 1.0), ev(1, 1, 1, 2.0), ev(2, 2, 2, 3.0)];
        let mut src = ReplaySource::new(events);
        assert_eq!(src.remaining(), 3);
        let b0 = src.next_batch(2).unwrap();
        assert_eq!(b0.seq, 0);
        assert_eq!(b0.events.len(), 2);
        assert_eq!(b0.events[0].t, 1, "sorted by timestamp");
        assert_eq!(b0.events[1].t, 2);
        let b1 = src.next_batch(2).unwrap();
        assert_eq!(b1.seq, 1);
        assert_eq!(b1.events.len(), 1);
        assert_eq!(b1.events[0].t, 3);
        assert!(src.next_batch(2).is_none());
        assert_eq!(src.remaining(), 0);
    }

    #[test]
    fn replay_from_entries_translates_external_ids() {
        let mut map = IdMap::new();
        map.intern_user(100);
        map.intern_item(9000);
        let entries = vec![Entry { u: 0, v: 0, r: 4.0 }];
        let mut src = ReplaySource::from_entries(&entries, Some(&map));
        let b = src.next_batch(8).unwrap();
        assert_eq!(b.events[0].u, 100);
        assert_eq!(b.events[0].v, 9000);
        assert_eq!(b.events[0].r, 4.0);
    }

    #[test]
    fn channel_source_drains_and_terminates() {
        let (tx, mut src) = ChannelSource::new(Duration::from_millis(5));
        for i in 0..5u64 {
            tx.send(ev(i, i, i, 1.0)).unwrap();
        }
        let b = src.next_batch(3).unwrap();
        assert_eq!(b.events.len(), 3);
        let b = src.next_batch(10).unwrap();
        assert_eq!(b.events.len(), 2);
        drop(tx);
        assert!(src.next_batch(4).is_none(), "closed + empty ⇒ exhausted");
    }

    #[test]
    fn shard_replay_streams_external_ids_in_order() {
        let dir = std::env::temp_dir().join("a2psgd_shard_replay_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // External ids 100, 110, … / 9000, 9001, … — must come back external.
        let triplets: Vec<(u64, u64, f32)> = (0..50u64)
            .map(|i| (100 + (i % 10) * 10, 9000 + i / 10, (i % 5) as f32 + 1.0))
            .collect();
        let opts = crate::data::shard::PackOptions { shard_bytes: 128 };
        let stats = crate::data::shard::pack_triplets(&triplets, &dir, &opts).unwrap();
        assert!(stats.shards >= 2, "want a multi-shard replay");
        let mut src = ShardReplaySource::with_chunk(&dir, 7).unwrap();
        assert_eq!(src.remaining(), stats.nnz);
        let mut events = Vec::new();
        while let Some(b) = src.next_batch(8) {
            assert!(b.events.len() <= 8);
            events.extend(b.events);
        }
        assert!(src.error().is_none());
        assert_eq!(events.len() as u64, stats.nnz);
        assert_eq!(src.remaining(), 0);
        // Timestamps are the canonical record index.
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.t, i as u64);
            assert!(e.u >= 100 && e.v >= 9000, "external ids must survive: {e:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_replay_skip_shards_replays_the_row_suffix() {
        let dir = std::env::temp_dir().join("a2psgd_shard_replay_skip_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let triplets: Vec<(u64, u64, f32)> = (0..60u64)
            .map(|i| (i / 6, i % 6, (i % 5) as f32 + 1.0))
            .collect();
        let opts = crate::data::shard::PackOptions { shard_bytes: 128 };
        let stats = crate::data::shard::pack_triplets(&triplets, &dir, &opts).unwrap();
        assert!(stats.shards >= 3, "want several shards, got {}", stats.shards);
        let manifest = crate::data::shard::Manifest::load(&dir).unwrap();
        let head_nnz: u64 = manifest.shards[..2].iter().map(|m| m.nnz).sum();
        let cut_row = manifest.shards[1].row_hi as u64;
        let mut src = ShardReplaySource::with_chunk(&dir, 5).unwrap().skip_shards(2);
        assert_eq!(src.remaining(), stats.nnz - head_nnz);
        let mut events = Vec::new();
        while let Some(b) = src.next_batch(7) {
            events.extend(b.events);
        }
        assert!(src.error().is_none());
        assert_eq!(events.len() as u64, stats.nnz - head_nnz);
        // Timestamps continue the global record index; only suffix rows
        // (external id == dense id here — identity-free synthetic pack).
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.t, head_nnz + i as u64);
            assert!(e.u >= cut_row, "event {e:?} below the cut row {cut_row}");
        }
        // Skipping everything yields an exhausted stream.
        let mut none = ShardReplaySource::open(&dir).unwrap().skip_shards(99);
        assert_eq!(none.remaining(), 0);
        assert!(none.next_batch(4).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn channel_source_partial_batch_on_timeout() {
        let (tx, mut src) = ChannelSource::new(Duration::from_millis(1));
        tx.send(ev(0, 0, 0, 1.0)).unwrap();
        let b = src.next_batch(100).unwrap();
        assert_eq!(b.events.len(), 1, "deadline flushes a partial batch");
        // Sender still alive: source must keep yielding later batches.
        tx.send(ev(1, 1, 1, 2.0)).unwrap();
        assert_eq!(src.next_batch(100).unwrap().events.len(), 1);
    }
}

//! Event ingestion: timestamped interaction events, bounded micro-batches,
//! and the sources that produce them.
//!
//! Two sources cover the production and benchmarking stories:
//!
//! - [`ChannelSource`] — a live source fed through an [`EventSender`] from
//!   any number of producer threads; `next_batch` drains up to the batch
//!   bound or a wait deadline, so ingestion latency is bounded even under
//!   trickle traffic.
//! - [`ReplaySource`] — replays a recorded interaction log (e.g. any
//!   existing [`crate::data::Dataset`]'s entries) in timestamp order as a
//!   simulated live stream, which is what the benchmarks and the
//!   `online_serving` example drive.

use crate::data::loader::IdMap;
use crate::sparse::{CooMatrix, Entry};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One timestamped interaction observed on the stream. Node ids are
/// *external* (application key space); the online trainer resolves them to
/// dense ids through an [`IdMap`], growing the factors for unseen nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Event time (any monotone unit — replay uses the log position).
    pub t: u64,
    /// External user id.
    pub u: u64,
    /// External item id.
    pub v: u64,
    /// Interaction weight / rating.
    pub r: f32,
}

/// A bounded micro-batch of events, in arrival order.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Monotone batch sequence number (0-based per source).
    pub seq: u64,
    /// The events (non-empty; length ≤ the requested bound).
    pub events: Vec<Event>,
}

/// Anything that yields bounded micro-batches of interaction events.
pub trait EventSource {
    /// Next micro-batch of at most `max_events` (≥ 1) events, or `None`
    /// when the stream is exhausted. Never returns an empty batch.
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch>;
}

/// Replay a recorded event log as a simulated live stream.
#[derive(Clone, Debug)]
pub struct ReplaySource {
    events: Vec<Event>,
    pos: usize,
    seq: u64,
}

impl ReplaySource {
    /// Replay `events` in timestamp order (stable for equal timestamps).
    pub fn new(mut events: Vec<Event>) -> Self {
        events.sort_by_key(|e| e.t);
        ReplaySource { events, pos: 0, seq: 0 }
    }

    /// Replay a dense COO matrix; external ids are the dense ids and the
    /// timestamp is the entry's position in the log.
    pub fn from_coo(coo: &CooMatrix) -> Self {
        Self::from_entries(coo.entries(), None)
    }

    /// Replay dense entries, optionally translating back to external ids
    /// through `map` (entries whose dense ids the map does not know keep
    /// their dense id as the external id).
    pub fn from_entries(entries: &[Entry], map: Option<&IdMap>) -> Self {
        let events = entries
            .iter()
            .enumerate()
            .map(|(i, e)| Event {
                t: i as u64,
                u: map
                    .and_then(|m| m.external_user(e.u))
                    .unwrap_or(e.u as u64),
                v: map
                    .and_then(|m| m.external_item(e.v))
                    .unwrap_or(e.v as u64),
                r: e.r,
            })
            .collect();
        ReplaySource { events, pos: 0, seq: 0 }
    }

    /// Events not yet replayed.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.pos
    }
}

impl EventSource for ReplaySource {
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch> {
        assert!(max_events >= 1);
        if self.pos >= self.events.len() {
            return None;
        }
        let end = (self.pos + max_events).min(self.events.len());
        let events = self.events[self.pos..end].to_vec();
        self.pos = end;
        let seq = self.seq;
        self.seq += 1;
        Some(MicroBatch { seq, events })
    }
}

/// Producer handle for a [`ChannelSource`]; cloneable across threads.
#[derive(Clone)]
pub struct EventSender {
    tx: mpsc::Sender<Event>,
}

impl EventSender {
    /// Enqueue one event; fails once the source has been dropped.
    pub fn send(&self, e: Event) -> crate::Result<()> {
        self.tx.send(e).map_err(|_| anyhow::anyhow!("event source closed"))
    }
}

/// A live event source fed through a channel.
pub struct ChannelSource {
    rx: mpsc::Receiver<Event>,
    max_wait: Duration,
    seq: u64,
}

impl ChannelSource {
    /// Create the source plus its producer handle. `max_wait` bounds how
    /// long a partially filled micro-batch waits for more events.
    pub fn new(max_wait: Duration) -> (EventSender, ChannelSource) {
        let (tx, rx) = mpsc::channel();
        (EventSender { tx }, ChannelSource { rx, max_wait, seq: 0 })
    }
}

impl EventSource for ChannelSource {
    /// Blocks for the first event, then drains until `max_events` or the
    /// `max_wait` deadline. Returns `None` once every sender has dropped
    /// and the queue is empty.
    fn next_batch(&mut self, max_events: usize) -> Option<MicroBatch> {
        assert!(max_events >= 1);
        let first = self.rx.recv().ok()?;
        let mut events = Vec::with_capacity(max_events.min(1024));
        events.push(first);
        let deadline = Instant::now() + self.max_wait;
        while events.len() < max_events {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(e) => events.push(e),
                Err(_) => break, // timeout or disconnected — ship what we have
            }
        }
        let seq = self.seq;
        self.seq += 1;
        Some(MicroBatch { seq, events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, u: u64, v: u64, r: f32) -> Event {
        Event { t, u, v, r }
    }

    #[test]
    fn replay_batches_are_bounded_and_ordered() {
        let events = vec![ev(3, 0, 0, 1.0), ev(1, 1, 1, 2.0), ev(2, 2, 2, 3.0)];
        let mut src = ReplaySource::new(events);
        assert_eq!(src.remaining(), 3);
        let b0 = src.next_batch(2).unwrap();
        assert_eq!(b0.seq, 0);
        assert_eq!(b0.events.len(), 2);
        assert_eq!(b0.events[0].t, 1, "sorted by timestamp");
        assert_eq!(b0.events[1].t, 2);
        let b1 = src.next_batch(2).unwrap();
        assert_eq!(b1.seq, 1);
        assert_eq!(b1.events.len(), 1);
        assert_eq!(b1.events[0].t, 3);
        assert!(src.next_batch(2).is_none());
        assert_eq!(src.remaining(), 0);
    }

    #[test]
    fn replay_from_entries_translates_external_ids() {
        let mut map = IdMap::new();
        map.intern_user(100);
        map.intern_item(9000);
        let entries = vec![Entry { u: 0, v: 0, r: 4.0 }];
        let mut src = ReplaySource::from_entries(&entries, Some(&map));
        let b = src.next_batch(8).unwrap();
        assert_eq!(b.events[0].u, 100);
        assert_eq!(b.events[0].v, 9000);
        assert_eq!(b.events[0].r, 4.0);
    }

    #[test]
    fn channel_source_drains_and_terminates() {
        let (tx, mut src) = ChannelSource::new(Duration::from_millis(5));
        for i in 0..5u64 {
            tx.send(ev(i, i, i, 1.0)).unwrap();
        }
        let b = src.next_batch(3).unwrap();
        assert_eq!(b.events.len(), 3);
        let b = src.next_batch(10).unwrap();
        assert_eq!(b.events.len(), 2);
        drop(tx);
        assert!(src.next_batch(4).is_none(), "closed + empty ⇒ exhausted");
    }

    #[test]
    fn channel_source_partial_batch_on_timeout() {
        let (tx, mut src) = ChannelSource::new(Duration::from_millis(1));
        tx.send(ev(0, 0, 0, 1.0)).unwrap();
        let b = src.next_batch(100).unwrap();
        assert_eq!(b.events.len(), 1, "deadline flushes a partial batch");
        // Sender still alive: source must keep yielding later batches.
        tx.send(ev(1, 1, 1, 2.0)).unwrap();
        assert_eq!(src.next_batch(100).unwrap().events.len(), 1);
    }
}

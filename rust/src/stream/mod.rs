//! Online learning subsystem: streaming ingestion, incremental fold-in, and
//! continuous training with zero-downtime factor hot-swap.
//!
//! The paper's HDS matrices "describe real-world node interactions" — and
//! real interaction streams never stop. This subsystem keeps a trained LR
//! model live against such a stream:
//!
//! 1. [`source`] turns timestamped `(u, v, r)` events into bounded
//!    micro-batches ([`ReplaySource`] simulates a live stream from any
//!    recorded log; [`ChannelSource`] ingests from producer threads).
//! 2. [`foldin`] grows the factor matrices for never-before-seen nodes and
//!    runs a few one-sided NAG steps on just the new node's row.
//! 3. [`online::OnlineTrainer`] applies sliding-window incremental NAG
//!    updates on worker threads through the lock-free block scheduler
//!    (exactly the A²PSGD machinery, pointed at the recent-events window)
//!    and periodically publishes refreshed factors.
//! 4. [`crate::model::snapshot::SnapshotStore`] delivers each published
//!    generation to the prediction service atomically — the service pins a
//!    snapshot per batch and never restarts (see the module docs there for
//!    the full protocol).
//!
//! `a2psgd stream` drives the whole pipeline from the CLI, and
//! `examples/online_serving.rs` demonstrates predictions improving live.

pub mod foldin;
pub mod online;
pub mod source;

pub use online::{OnlineStats, OnlineTrainer};
pub use source::{
    ChannelSource, Event, EventSender, EventSource, MicroBatch, ReplaySource, ShardReplaySource,
};

use crate::data::loader::IdMap;
use crate::data::Dataset;
use crate::optim::{Hyper, Rule};
use crate::rng::Rng;
use crate::sparse::CooMatrix;
use crate::Result;

/// Configuration of the online trainer (the `stream` preset).
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    /// Max events per ingested micro-batch.
    pub batch: usize,
    /// Sliding-window capacity (most recent trainable events kept).
    pub window: usize,
    /// Full sweeps over the window per ingested batch.
    pub passes: u32,
    /// Publish a fresh snapshot every this many batches (≥ 1).
    pub publish_every: u64,
    /// One-sided NAG sweeps when folding in a new node.
    pub foldin_steps: u32,
    /// Every k-th event is held out for rolling evaluation instead of
    /// trained on (≥ 2; the ring is the online test set).
    pub holdout_every: u64,
    /// Rolling-holdout ring capacity.
    pub holdout_cap: usize,
    /// Worker threads for window updates.
    pub threads: usize,
    /// η / λ / γ for both window updates and fold-in.
    pub hyper: Hyper,
    /// Update rule for window sweeps (fold-in is always one-sided NAG).
    pub rule: Rule,
    /// Update-kernel selection for window sweeps (SIMD auto-dispatch vs
    /// forced scalar; `A2PSGD_KERNEL=scalar` overrides).
    pub kernel: crate::optim::kernel::KernelChoice,
    /// RNG seed (new-row init, window shuffling, scheduling).
    pub seed: u64,
}

impl StreamConfig {
    /// The `stream` preset for a dataset: A²PSGD hyperparameters (Tables
    /// I/II families) with streaming defaults sized for micro-batch work.
    pub fn preset(dataset_name: &str) -> Self {
        StreamConfig {
            batch: 256,
            window: 4096,
            passes: 2,
            publish_every: 4,
            foldin_steps: 10,
            holdout_every: 8,
            holdout_cap: 1024,
            threads: crate::engine::default_threads(),
            hyper: crate::config::presets::hyper_for(crate::engine::EngineKind::A2psgd, dataset_name),
            rule: Rule::Nag,
            kernel: crate::optim::kernel::KernelChoice::Auto,
            seed: 0x5EED,
        }
    }

    /// Builder: micro-batch bound.
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b.max(1);
        self
    }

    /// Builder: sliding-window capacity.
    pub fn window(mut self, w: usize) -> Self {
        self.window = w.max(1);
        self
    }

    /// Builder: publish cadence in batches.
    pub fn publish_every(mut self, n: u64) -> Self {
        self.publish_every = n.max(1);
        self
    }

    /// Builder: fold-in sweeps.
    pub fn foldin_steps(mut self, n: u32) -> Self {
        self.foldin_steps = n;
        self
    }

    /// Builder: worker threads.
    pub fn threads(mut self, c: usize) -> Self {
        self.threads = c.max(1);
        self
    }

    /// Builder: hyperparameters.
    pub fn hyper(mut self, h: Hyper) -> Self {
        self.hyper = h;
        self
    }

    /// Builder: seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Builder: update-kernel selection policy.
    pub fn kernel(mut self, k: crate::optim::kernel::KernelChoice) -> Self {
        self.kernel = k;
        self
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.batch >= 1, "stream.batch must be ≥ 1");
        anyhow::ensure!(self.window >= 1, "stream.window must be ≥ 1");
        anyhow::ensure!(self.publish_every >= 1, "stream.publish_every must be ≥ 1");
        anyhow::ensure!(self.holdout_every >= 2, "stream.holdout_every must be ≥ 2");
        anyhow::ensure!(self.holdout_cap >= 1, "stream.holdout_cap must be ≥ 1");
        anyhow::ensure!(self.threads >= 1, "stream.threads must be ≥ 1");
        Ok(())
    }
}

/// A dataset split for replay benchmarking: a *warm* prefix of users to
/// train offline, plus the remaining (*cold*) users' interactions as a
/// simulated live stream of external-id events.
pub struct ReplaySplit {
    /// Offline-training dataset restricted to the warm users.
    pub warm: Dataset,
    /// External↔dense map covering exactly the warm dataset (identity).
    pub map: IdMap,
    /// The cold users' interactions, shuffled, as a replayable stream.
    pub stream: ReplaySource,
    /// Number of users withheld from warm training.
    pub n_cold_users: u32,
}

/// Split `data` so the first `warm_user_frac` of users form the offline
/// training set and every interaction of the remaining users becomes a
/// stream event (external ids = the original dense ids of `data`).
pub fn replay_split(data: &Dataset, warm_user_frac: f64, seed: u64) -> ReplaySplit {
    let nrows = data.nrows();
    let warm_rows = ((nrows as f64 * warm_user_frac).ceil() as u32).clamp(1, nrows);
    let mut warm_train = CooMatrix::new(warm_rows, data.ncols());
    let mut warm_test = CooMatrix::new(warm_rows, data.ncols());
    let mut cold = Vec::new();
    for e in data.train.entries() {
        if e.u < warm_rows {
            warm_train.push(e.u, e.v, e.r).expect("warm entry in range");
        } else {
            cold.push(*e);
        }
    }
    for e in data.test.entries() {
        if e.u < warm_rows {
            warm_test.push(e.u, e.v, e.r).expect("warm entry in range");
        } else {
            cold.push(*e);
        }
    }
    let mut rng = Rng::new(seed ^ 0x57EEA4);
    rng.shuffle(&mut cold);
    let events: Vec<Event> = cold
        .iter()
        .enumerate()
        .map(|(i, e)| Event { t: i as u64, u: e.u as u64, v: e.v as u64, r: e.r })
        .collect();
    ReplaySplit {
        warm: Dataset {
            name: format!("{}-warm", data.name),
            train: warm_train,
            test: warm_test,
            rating_min: data.rating_min,
            rating_max: data.rating_max,
        },
        map: IdMap::identity(warm_rows, data.ncols()),
        stream: ReplaySource::new(events),
        n_cold_users: nrows - warm_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn preset_is_valid_and_uses_a2_hypers() {
        let cfg = StreamConfig::preset("ml1m-twin");
        cfg.validate().unwrap();
        assert!(cfg.hyper.gamma > 0.0, "stream preset must use NAG hypers");
        assert_eq!(cfg.rule, Rule::Nag);
    }

    #[test]
    fn builders_clamp_degenerate_values() {
        let cfg = StreamConfig::preset("small").batch(0).window(0).publish_every(0).threads(0);
        assert_eq!(cfg.batch, 1);
        assert_eq!(cfg.window, 1);
        assert_eq!(cfg.publish_every, 1);
        assert_eq!(cfg.threads, 1);
    }

    #[test]
    fn replay_split_partitions_every_interaction() {
        let data = synthetic::small(5);
        let split = replay_split(&data, 0.8, 42);
        assert!(split.n_cold_users > 0);
        assert_eq!(split.warm.nrows() + split.n_cold_users, data.nrows());
        let warm_total = split.warm.total_nnz();
        assert_eq!(warm_total + split.stream.remaining(), data.total_nnz());
        // Warm entries only reference warm users.
        assert!(split
            .warm
            .train
            .entries()
            .iter()
            .all(|e| e.u < split.warm.nrows()));
        // The id map is the identity over the warm shape.
        assert_eq!(split.map.n_users(), split.warm.nrows());
        assert_eq!(split.map.n_items(), data.ncols());
        assert_eq!(split.map.user(0), Some(0));
    }

    #[test]
    fn replay_split_stream_has_only_cold_users() {
        let data = synthetic::small(6);
        let mut split = replay_split(&data, 0.9, 1);
        let warm_rows = split.warm.nrows() as u64;
        while let Some(b) = split.stream.next_batch(512) {
            assert!(b.events.iter().all(|e| e.u >= warm_rows));
        }
    }
}

//! Incremental fold-in for never-before-seen nodes.
//!
//! When a new user (or item) appears on the stream, its factor row is grown
//! ([`crate::model::Factors::grow_rows`]/`grow_cols`) with a mean-matched
//! random init and then refined by a few *one-sided* NAG steps against the
//! node's observed entries only: the established side of the factorization
//! is frozen, so fold-in is cheap (O(steps · |obs| · D)), touches no other
//! node's state, and cannot destabilize the serving model. The regular
//! sliding-window online updates then keep improving both sides.

use crate::model::Factors;
use crate::optim::Hyper;

/// One-sided NAG refinement of user row `u` against observed `(item, r)`
/// pairs; item rows are read-only. `steps` full sweeps over `obs`.
pub fn fold_in_user(f: &mut Factors, u: u32, obs: &[(u32, f32)], h: &Hyper, steps: u32) {
    let d = f.d();
    assert!(u < f.nrows(), "fold-in user {u} out of range {}", f.nrows());
    let ncols = f.ncols();
    let g = h.gamma;
    let (m, phi, n) = (&mut f.m, &mut f.phi, &f.n);
    let mu = &mut m[u as usize * d..(u as usize + 1) * d];
    let phiu = &mut phi[u as usize * d..(u as usize + 1) * d];
    for _ in 0..steps {
        for &(v, r) in obs {
            assert!(v < ncols, "fold-in item {v} out of range {ncols}");
            let nv = &n[v as usize * d..(v as usize + 1) * d];
            one_sided_nag(mu, phiu, nv, r, h.eta, h.lam, g);
        }
    }
}

/// One-sided NAG refinement of item row `v` against observed `(user, r)`
/// pairs; user rows are read-only. Mirror of [`fold_in_user`].
pub fn fold_in_item(f: &mut Factors, v: u32, obs: &[(u32, f32)], h: &Hyper, steps: u32) {
    let d = f.d();
    assert!(v < f.ncols(), "fold-in item {v} out of range {}", f.ncols());
    let nrows = f.nrows();
    let g = h.gamma;
    let (n, psi, m) = (&mut f.n, &mut f.psi, &f.m);
    let nv = &mut n[v as usize * d..(v as usize + 1) * d];
    let psiv = &mut psi[v as usize * d..(v as usize + 1) * d];
    for _ in 0..steps {
        for &(u, r) in obs {
            assert!(u < nrows, "fold-in user {u} out of range {nrows}");
            let mu = &m[u as usize * d..(u as usize + 1) * d];
            one_sided_nag(nv, psiv, mu, r, h.eta, h.lam, g);
        }
    }
}

/// One NAG step on `row` (momentum `mom`) against frozen `other`:
/// look-ahead `x̂ = x + γφ`, error at the look-ahead, then
/// `φ ← γφ + η(e·other − λx̂)`, `x ← x + φ`.
#[inline]
fn one_sided_nag(row: &mut [f32], mom: &mut [f32], other: &[f32], r: f32, eta: f32, lam: f32, g: f32) {
    debug_assert_eq!(row.len(), other.len());
    let mut dot = 0f32;
    for k in 0..row.len() {
        dot += (row[k] + g * mom[k]) * other[k];
    }
    let e = r - dot;
    let ee = eta * e;
    let el = eta * lam;
    for k in 0..row.len() {
        let xh = row[k] + g * mom[k];
        let pk = g * mom[k] + ee * other[k] - el * xh;
        mom[k] = pk;
        row[k] += pk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn factors(seed: u64) -> Factors {
        let mut rng = Rng::new(seed);
        Factors::init(6, 8, 4, Factors::default_scale(3.0, 4), &mut rng)
    }

    fn sq_err(f: &Factors, u: u32, obs: &[(u32, f32)]) -> f64 {
        obs.iter()
            .map(|&(v, r)| {
                let d = (r - f.predict(u, v)) as f64;
                d * d
            })
            .sum::<f64>()
            / obs.len() as f64
    }

    #[test]
    fn fold_in_user_fits_observed_entries() {
        let mut f = factors(1);
        let mut rng = Rng::new(9);
        f.grow_rows(1, Factors::default_scale(3.0, 4), &mut rng);
        let u = 6;
        let obs = vec![(0u32, 4.0f32), (3, 2.0), (7, 5.0)];
        let h = Hyper::nag(0.05, 0.01, 0.9);
        let e0 = sq_err(&f, u, &obs);
        fold_in_user(&mut f, u, &obs, &h, 30);
        let e1 = sq_err(&f, u, &obs);
        assert!(e1 < 0.2 * e0, "fold-in must fit observations: {e0} → {e1}");
        assert!(f.m.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn fold_in_user_freezes_everything_else() {
        let mut f = factors(2);
        let n0 = f.n.clone();
        let psi0 = f.psi.clone();
        let m_other: Vec<f32> = f.m_row(0).to_vec();
        fold_in_user(&mut f, 5, &[(1, 4.0), (2, 1.0)], &Hyper::nag(0.05, 0.01, 0.9), 10);
        assert_eq!(f.n, n0, "item factors must not move");
        assert_eq!(f.psi, psi0);
        assert_eq!(f.m_row(0), &m_other[..], "other user rows must not move");
    }

    #[test]
    fn fold_in_item_fits_and_freezes() {
        let mut f = factors(3);
        let mut rng = Rng::new(11);
        f.grow_cols(1, Factors::default_scale(3.0, 4), &mut rng);
        let v = 8;
        let obs = vec![(0u32, 3.5f32), (2, 1.5), (5, 4.5)];
        let h = Hyper::nag(0.05, 0.01, 0.9);
        let m0 = f.m.clone();
        let e0: f64 = obs.iter().map(|&(u, r)| ((r - f.predict(u, v)) as f64).powi(2)).sum();
        fold_in_item(&mut f, v, &obs, &h, 30);
        let e1: f64 = obs.iter().map(|&(u, r)| ((r - f.predict(u, v)) as f64).powi(2)).sum();
        assert!(e1 < 0.2 * e0, "{e0} → {e1}");
        assert_eq!(f.m, m0, "user factors must not move");
    }

    #[test]
    fn gamma_zero_reduces_to_one_sided_sgd() {
        // With γ=0 and λ=0, one step on a single observation moves the row
        // by exactly η·e·n_v.
        let mut f = factors(4);
        let u = 1;
        let v = 2;
        let r = 4.0;
        let before: Vec<f32> = f.m_row(u).to_vec();
        let nv: Vec<f32> = f.n_row(v).to_vec();
        let e = r - f.predict(u, v);
        fold_in_user(&mut f, u, &[(v, r)], &Hyper::nag(0.1, 0.0, 0.0), 1);
        for k in 0..f.d() {
            let want = before[k] + 0.1 * e * nv[k];
            assert!((f.m_row(u)[k] - want).abs() < 1e-6);
        }
    }
}

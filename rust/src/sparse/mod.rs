//! Sparse-matrix substrate for HDS data.
//!
//! The paper's object is an HDS matrix `R^{|U|×|V|}` with known-instance set
//! Ω (Definition 1). [`CooMatrix`] is the ingestion/blocking format;
//! [`CsrMatrix`] serves row-major sweeps (ASGD's M-phase) and its transpose
//! the column sweeps; [`BlockCsr`] is the hot-path block-local CSR layout
//! every training engine's inner loop walks (behind the [`SweepLanes`]
//! iteration contract); [`stats`] computes the marginal-count skew measures
//! the load-balancing study reports.

mod block_csr;
mod coo;
mod csr;
pub mod stats;

pub use block_csr::{BlockCsr, CsrRowRange, EntryLanes, LaneSlice, SweepLanes};
pub use coo::{dedup_keep_last, CooMatrix, Entry};
pub use csr::CsrMatrix;

//! Block-local CSR storage — the hot-path memory layout for block-scheduled
//! training (Hogwild!'s observation, applied to blocks: for sparse SGD the
//! memory layout dominates wall-clock).
//!
//! The pre-CSR layout kept each sub-block as `Vec<Entry>` — an AoS list of
//! `(u, v, r)` triplets with *global* node ids. A block sweep then walked
//! 12-byte structs and recomputed `u * d` / `v * d` factor offsets from
//! 32-bit global ids every instance. [`BlockCsr`] replaces that with three
//! contiguous lanes `(local_u, local_v, r)` in block-local coordinates plus
//! per-block base offsets:
//!
//! - the sweep walks three sequential arrays (SoA — no struct padding, unit
//!   stride for the prefetcher);
//! - instances are counting-sorted into block-local CSR order (row-major
//!   within the block, `indptr` over local rows), so consecutive instances
//!   share the same factor row `m_u` far more often — that row stays in L1
//!   across its whole run;
//! - local ids are dense small integers; the base offsets are added back
//!   once per instance to index the factor matrices, with no per-entry
//!   global-id indirection table.
//!
//! [`SweepLanes`] is the shared iteration contract every engine's inner
//! loop goes through: [`BlockCsr`] for the block-scheduled engines (FPSGD,
//! A²PSGD, DSGD), [`EntryLanes`]/[`LaneSlice`] for the flat-order engines
//! (Seq, Hogwild!), and [`CsrRowRange`] for ASGD's row/column phase sweeps.

use super::coo::{CooMatrix, Entry};
use super::csr::CsrMatrix;
use crate::rng::Rng;

/// Shared iteration contract for every engine's instance sweep.
///
/// Implementors yield instances as `(global_u, global_v, r)` so the caller
/// can index the factor matrices directly; how the instances are stored
/// (block-local lanes, flat lanes, CSR rows) is the implementor's business.
pub trait SweepLanes {
    /// Number of instances this sweep will visit.
    fn n_instances(&self) -> usize;

    /// Visit every instance as `(global_u, global_v, r)` in storage order.
    /// Returns the number of instances visited.
    fn sweep<F: FnMut(u32, u32, f32)>(&self, f: F) -> u64;
}

/// One sub-block R_ij in block-local CSR layout (see module docs).
#[derive(Clone, Debug, Default)]
pub struct BlockCsr {
    row_base: u32,
    col_base: u32,
    row_span: u32,
    col_span: u32,
    /// CSR index over local rows (`row_span + 1` entries); emptied by
    /// [`BlockCsr::shuffle`], which abandons CSR order.
    indptr: Vec<u32>,
    local_u: Vec<u32>,
    local_v: Vec<u32>,
    r: Vec<f32>,
}

impl BlockCsr {
    /// Empty block covering global rows `row_base..row_base + row_span` and
    /// columns `col_base..col_base + col_span`, with lane capacity `cap`.
    pub fn with_capacity(
        row_base: u32,
        row_span: u32,
        col_base: u32,
        col_span: u32,
        cap: usize,
    ) -> Self {
        BlockCsr {
            row_base,
            col_base,
            row_span,
            col_span,
            indptr: Vec::new(),
            local_u: Vec::with_capacity(cap),
            local_v: Vec::with_capacity(cap),
            r: Vec::with_capacity(cap),
        }
    }

    /// Append one instance by *global* ids (converted to block-local).
    /// Call [`BlockCsr::finalize`] once all instances are in.
    pub fn push(&mut self, u: u32, v: u32, r: f32) {
        debug_assert!(
            u >= self.row_base && u - self.row_base < self.row_span,
            "row {u} outside block rows {}..{}",
            self.row_base,
            self.row_base + self.row_span
        );
        debug_assert!(
            v >= self.col_base && v - self.col_base < self.col_span,
            "col {v} outside block cols {}..{}",
            self.col_base,
            self.col_base + self.col_span
        );
        self.local_u.push(u - self.row_base);
        self.local_v.push(v - self.col_base);
        self.r.push(r);
    }

    /// Counting-sort the lanes into block-local CSR order (row-major over
    /// local rows; within-row order preserves insertion order) and build
    /// `indptr`. Idempotent on an already-finalized block.
    pub fn finalize(&mut self) {
        let span = self.row_span as usize;
        let mut indptr = vec![0u32; span + 1];
        for &lu in &self.local_u {
            indptr[lu as usize + 1] += 1;
        }
        for k in 1..indptr.len() {
            indptr[k] += indptr[k - 1];
        }
        let mut cursor = indptr.clone();
        let n = self.local_u.len();
        let mut lu2 = vec![0u32; n];
        let mut lv2 = vec![0u32; n];
        let mut r2 = vec![0f32; n];
        for k in 0..n {
            let row = self.local_u[k] as usize;
            let p = cursor[row] as usize;
            lu2[p] = self.local_u[k];
            lv2[p] = self.local_v[k];
            r2[p] = self.r[k];
            cursor[row] += 1;
        }
        self.local_u = lu2;
        self.local_v = lv2;
        self.r = r2;
        self.indptr = indptr;
    }

    /// Number of instances in the block.
    pub fn len(&self) -> usize {
        self.local_u.len()
    }

    /// True when the block holds no instances.
    pub fn is_empty(&self) -> bool {
        self.local_u.is_empty()
    }

    /// First global row covered by the block.
    pub fn row_base(&self) -> u32 {
        self.row_base
    }

    /// First global column covered by the block.
    pub fn col_base(&self) -> u32 {
        self.col_base
    }

    /// Number of local rows the block spans.
    pub fn row_span(&self) -> u32 {
        self.row_span
    }

    /// Number of local columns the block spans.
    pub fn col_span(&self) -> u32 {
        self.col_span
    }

    /// The raw `(local_u, local_v, r)` lanes.
    pub fn lanes(&self) -> (&[u32], &[u32], &[f32]) {
        (&self.local_u, &self.local_v, &self.r)
    }

    /// CSR index over local rows. Empty when the block was never finalized
    /// or its order was abandoned by [`BlockCsr::shuffle`].
    pub fn indptr(&self) -> &[u32] {
        &self.indptr
    }

    /// Instances in one local row (requires CSR order).
    pub fn row_nnz(&self, local_row: u32) -> usize {
        assert!(
            !self.indptr.is_empty(),
            "row_nnz requires CSR order (finalize, and don't shuffle)"
        );
        (self.indptr[local_row as usize + 1] - self.indptr[local_row as usize]) as usize
    }

    /// Instance `k` as `(global_u, global_v, r)`.
    #[inline]
    pub fn get(&self, k: usize) -> (u32, u32, f32) {
        (
            self.row_base + self.local_u[k],
            self.col_base + self.local_v[k],
            self.r[k],
        )
    }

    /// Iterate instances as global-id [`Entry`] values (tests/diagnostics;
    /// the hot path uses [`SweepLanes::sweep`]).
    pub fn iter_global(&self) -> impl Iterator<Item = Entry> + '_ {
        (0..self.len()).map(move |k| {
            let (u, v, r) = self.get(k);
            Entry { u, v, r }
        })
    }

    /// Synchronized Fisher–Yates shuffle of the three lanes (decorrelates
    /// the within-block visit order for SGD experiments). Abandons CSR
    /// order: `indptr` is cleared.
    pub fn shuffle(&mut self, rng: &mut Rng) {
        for i in (1..self.local_u.len()).rev() {
            let j = rng.gen_index(i + 1);
            self.local_u.swap(i, j);
            self.local_v.swap(i, j);
            self.r.swap(i, j);
        }
        self.indptr.clear();
    }
}

impl SweepLanes for BlockCsr {
    #[inline]
    fn n_instances(&self) -> usize {
        self.len()
    }

    #[inline]
    fn sweep<F: FnMut(u32, u32, f32)>(&self, mut f: F) -> u64 {
        let (rb, cb) = (self.row_base, self.col_base);
        for ((&lu, &lv), &r) in self.local_u.iter().zip(&self.local_v).zip(&self.r) {
            f(rb + lu, cb + lv, r);
        }
        self.local_u.len() as u64
    }
}

/// Flat structure-of-arrays instance storage (global ids) for the engines
/// that sweep the whole training set rather than blocks (Seq, Hogwild!).
#[derive(Clone, Debug, Default)]
pub struct EntryLanes {
    u: Vec<u32>,
    v: Vec<u32>,
    r: Vec<f32>,
}

impl EntryLanes {
    /// Build from an entry slice.
    pub fn from_entries(entries: &[Entry]) -> Self {
        let mut lanes = EntryLanes {
            u: Vec::with_capacity(entries.len()),
            v: Vec::with_capacity(entries.len()),
            r: Vec::with_capacity(entries.len()),
        };
        for e in entries {
            lanes.u.push(e.u);
            lanes.v.push(e.v);
            lanes.r.push(e.r);
        }
        lanes
    }

    /// Build from a COO matrix.
    pub fn from_coo(coo: &CooMatrix) -> Self {
        Self::from_entries(coo.entries())
    }

    /// Number of instances.
    pub fn len(&self) -> usize {
        self.u.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.u.is_empty()
    }

    /// Instance `k` as `(u, v, r)`.
    #[inline]
    pub fn get(&self, k: usize) -> (u32, u32, f32) {
        (self.u[k], self.v[k], self.r[k])
    }

    /// Synchronized Fisher–Yates shuffle of the three lanes.
    pub fn shuffle(&mut self, rng: &mut Rng) {
        for i in (1..self.u.len()).rev() {
            let j = rng.gen_index(i + 1);
            self.u.swap(i, j);
            self.v.swap(i, j);
            self.r.swap(i, j);
        }
    }

    /// Borrowed view of instances `lo..hi` (a worker's contiguous shard).
    pub fn slice(&self, lo: usize, hi: usize) -> LaneSlice<'_> {
        LaneSlice {
            u: &self.u[lo..hi],
            v: &self.v[lo..hi],
            r: &self.r[lo..hi],
        }
    }
}

impl SweepLanes for EntryLanes {
    fn n_instances(&self) -> usize {
        self.len()
    }

    fn sweep<F: FnMut(u32, u32, f32)>(&self, f: F) -> u64 {
        self.slice(0, self.len()).sweep(f)
    }
}

/// Borrowed lane view over a contiguous instance range of [`EntryLanes`].
#[derive(Clone, Copy, Debug)]
pub struct LaneSlice<'a> {
    u: &'a [u32],
    v: &'a [u32],
    r: &'a [f32],
}

impl LaneSlice<'_> {
    /// Number of instances in the view.
    pub fn len(&self) -> usize {
        self.u.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.u.is_empty()
    }

    /// Instance `k` as `(u, v, r)`.
    #[inline]
    pub fn get(&self, k: usize) -> (u32, u32, f32) {
        (self.u[k], self.v[k], self.r[k])
    }
}

impl SweepLanes for LaneSlice<'_> {
    #[inline]
    fn n_instances(&self) -> usize {
        self.len()
    }

    #[inline]
    fn sweep<F: FnMut(u32, u32, f32)>(&self, mut f: F) -> u64 {
        for ((&u, &v), &r) in self.u.iter().zip(self.v).zip(self.r) {
            f(u, v, r);
        }
        self.u.len() as u64
    }
}

/// Sweep over a contiguous row range of a [`CsrMatrix`] — ASGD's phase
/// shards behind the same iteration contract as the block engines. For the
/// transposed (N-phase) matrix the yielded `u` is the transpose's row, i.e.
/// the original column id.
#[derive(Clone, Copy, Debug)]
pub struct CsrRowRange<'a> {
    csr: &'a CsrMatrix,
    lo: u32,
    hi: u32,
}

impl<'a> CsrRowRange<'a> {
    /// View of rows `lo..hi`.
    pub fn new(csr: &'a CsrMatrix, lo: u32, hi: u32) -> Self {
        assert!(lo <= hi && hi <= csr.nrows(), "row range {lo}..{hi} out of bounds");
        CsrRowRange { csr, lo, hi }
    }
}

impl SweepLanes for CsrRowRange<'_> {
    fn n_instances(&self) -> usize {
        (self.lo..self.hi).map(|u| self.csr.row_nnz(u)).sum()
    }

    #[inline]
    fn sweep<F: FnMut(u32, u32, f32)>(&self, mut f: F) -> u64 {
        let mut n = 0u64;
        for u in self.lo..self.hi {
            let (idx, val) = self.csr.row(u);
            for (&v, &r) in idx.iter().zip(val.iter()) {
                f(u, v, r);
            }
            n += idx.len() as u64;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block() -> BlockCsr {
        // Block covering rows 10..14, cols 20..25.
        let mut b = BlockCsr::with_capacity(10, 4, 20, 5, 6);
        b.push(12, 21, 1.0);
        b.push(10, 24, 2.0);
        b.push(12, 20, 3.0);
        b.push(13, 22, 4.0);
        b.push(10, 20, 5.0);
        b.finalize();
        b
    }

    #[test]
    fn finalize_orders_rows_and_builds_indptr() {
        let b = block();
        assert_eq!(b.len(), 5);
        assert_eq!(b.indptr(), &[0, 2, 2, 4, 5]);
        assert_eq!(b.row_nnz(0), 2);
        assert_eq!(b.row_nnz(1), 0);
        assert_eq!(b.row_nnz(2), 2);
        // CSR order: local rows ascending, insertion order within a row.
        let (lu, _, _) = b.lanes();
        let mut sorted = lu.to_vec();
        sorted.sort_unstable();
        assert_eq!(lu, &sorted[..]);
    }

    #[test]
    fn get_restores_global_ids() {
        let b = block();
        let entries: Vec<Entry> = b.iter_global().collect();
        // Row-major: (10,24),(10,20) kept insertion order within row 0.
        assert_eq!(entries[0].u, 10);
        assert_eq!(entries[0].v, 24);
        assert_eq!(entries[0].r, 2.0);
        assert_eq!(entries[1], Entry { u: 10, v: 20, r: 5.0 });
        assert_eq!(entries[4], Entry { u: 13, v: 22, r: 4.0 });
        for e in &entries {
            assert!((10..14).contains(&e.u));
            assert!((20..25).contains(&e.v));
        }
    }

    #[test]
    fn sweep_visits_all_with_global_ids() {
        let b = block();
        let mut seen = Vec::new();
        let n = b.sweep(|u, v, r| seen.push(Entry { u, v, r }));
        assert_eq!(n, 5);
        assert_eq!(seen, b.iter_global().collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation_and_drops_indptr() {
        let mut b = block();
        let before: std::collections::BTreeSet<(u32, u32)> =
            b.iter_global().map(|e| (e.u, e.v)).collect();
        let mut rng = Rng::new(3);
        b.shuffle(&mut rng);
        let after: std::collections::BTreeSet<(u32, u32)> =
            b.iter_global().map(|e| (e.u, e.v)).collect();
        assert_eq!(before, after, "shuffle must preserve the instance set");
        assert!(b.indptr().is_empty(), "shuffle abandons CSR order");
        // Lanes stayed synchronized: every (u,v) still carries its rating.
        for e in b.iter_global() {
            let expect = match (e.u, e.v) {
                (12, 21) => 1.0,
                (10, 24) => 2.0,
                (12, 20) => 3.0,
                (13, 22) => 4.0,
                (10, 20) => 5.0,
                other => panic!("unexpected instance {other:?}"),
            };
            assert_eq!(e.r, expect);
        }
    }

    #[test]
    fn empty_block_finalizes() {
        let mut b = BlockCsr::with_capacity(0, 3, 0, 3, 0);
        b.finalize();
        assert!(b.is_empty());
        assert_eq!(b.indptr(), &[0, 0, 0, 0]);
        assert_eq!(b.sweep(|_, _, _| panic!("no instances")), 0);
    }

    #[test]
    fn entry_lanes_roundtrip_and_slice() {
        let entries = vec![
            Entry { u: 0, v: 1, r: 1.0 },
            Entry { u: 2, v: 3, r: 2.0 },
            Entry { u: 4, v: 5, r: 3.0 },
        ];
        let lanes = EntryLanes::from_entries(&entries);
        assert_eq!(lanes.len(), 3);
        assert_eq!(lanes.get(1), (2, 3, 2.0));
        let s = lanes.slice(1, 3);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(0), (2, 3, 2.0));
        let mut seen = Vec::new();
        assert_eq!(s.sweep(|u, v, r| seen.push((u, v, r))), 2);
        assert_eq!(seen, vec![(2, 3, 2.0), (4, 5, 3.0)]);
    }

    #[test]
    fn entry_lanes_shuffle_keeps_triples_together() {
        let entries: Vec<Entry> = (0..50)
            .map(|k| Entry { u: k, v: k + 100, r: k as f32 })
            .collect();
        let mut lanes = EntryLanes::from_entries(&entries);
        let mut rng = Rng::new(9);
        lanes.shuffle(&mut rng);
        let mut us = Vec::new();
        for k in 0..lanes.len() {
            let (u, v, r) = lanes.get(k);
            assert_eq!(v, u + 100);
            assert_eq!(r, u as f32);
            us.push(u);
        }
        us.sort_unstable();
        assert_eq!(us, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn csr_row_range_matches_rows() {
        let mut coo = CooMatrix::new(4, 4);
        coo.push(0, 1, 1.0).unwrap();
        coo.push(1, 2, 2.0).unwrap();
        coo.push(1, 0, 3.0).unwrap();
        coo.push(3, 3, 4.0).unwrap();
        let csr = CsrMatrix::from_coo(&coo);
        let range = CsrRowRange::new(&csr, 1, 3);
        assert_eq!(range.n_instances(), 2);
        let mut seen = Vec::new();
        assert_eq!(range.sweep(|u, v, r| seen.push((u, v, r))), 2);
        assert_eq!(seen, vec![(1, 2, 2.0), (1, 0, 3.0)]);
    }

    #[test]
    fn property_block_csr_preserves_instances() {
        crate::proptest_lite::check(
            "finalize preserves the multiset of instances",
            crate::testutil::budget(64, 12) as u32,
            |g| {
                let span = g.usize_in(1, 20) as u32;
                let n = g.usize_in(0, 80);
                let base = g.usize_in(0, 1000) as u32;
                let mut rng = Rng::new(g.u64(1 << 50));
                let entries: Vec<(u32, u32, f32)> = (0..n)
                    .map(|_| {
                        (
                            base + rng.gen_index(span as usize) as u32,
                            base + rng.gen_index(span as usize) as u32,
                            rng.f32(),
                        )
                    })
                    .collect();
                (base, span, entries)
            },
            |(base, span, entries)| {
                let mut b = BlockCsr::with_capacity(*base, *span, *base, *span, entries.len());
                for &(u, v, r) in entries {
                    b.push(u, v, r);
                }
                b.finalize();
                if b.len() != entries.len() {
                    return false;
                }
                let mut got: Vec<(u32, u32, u32)> = b
                    .iter_global()
                    .map(|e| (e.u, e.v, e.r.to_bits()))
                    .collect();
                let mut want: Vec<(u32, u32, u32)> =
                    entries.iter().map(|&(u, v, r)| (u, v, r.to_bits())).collect();
                got.sort_unstable();
                want.sort_unstable();
                // Also: indptr must be monotone and end at len.
                let ip = b.indptr();
                got == want
                    && ip.len() == *span as usize + 1
                    && ip.windows(2).all(|w| w[1] >= w[0])
                    && ip[*span as usize] as usize == entries.len()
            },
        );
    }
}

//! Compressed-sparse-row view used by the alternating (ASGD) engine and the
//! evaluators: M-phase sweeps user rows, N-phase sweeps the transpose.

use super::coo::{CooMatrix, Entry};

/// CSR sparse matrix over f32 weights.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    nrows: u32,
    ncols: u32,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a COO matrix (copies; COO order is preserved per row).
    pub fn from_coo(coo: &CooMatrix) -> Self {
        let nrows = coo.nrows();
        let ncols = coo.ncols();
        let nnz = coo.nnz();
        let mut counts = vec![0usize; nrows as usize + 1];
        for e in coo.entries() {
            counts[e.u as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        for e in coo.entries() {
            let p = cursor[e.u as usize];
            indices[p] = e.v;
            values[p] = e.r;
            cursor[e.u as usize] += 1;
        }
        CsrMatrix { nrows, ncols, indptr, indices, values }
    }

    /// Transpose (rows become columns) — the N-phase view.
    pub fn transpose(&self) -> CsrMatrix {
        let mut counts = vec![0usize; self.ncols as usize + 1];
        for &v in &self.indices {
            counts[v as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.indices.len()];
        let mut values = vec![0f32; self.values.len()];
        for u in 0..self.nrows as usize {
            for p in self.indptr[u]..self.indptr[u + 1] {
                let v = self.indices[p] as usize;
                let q = cursor[v];
                indices[q] = u as u32;
                values[q] = self.values[p];
                cursor[v] += 1;
            }
        }
        CsrMatrix {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            values,
        }
    }

    /// Number of rows.
    pub fn nrows(&self) -> u32 {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> u32 {
        self.ncols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (column indices, values) of one row.
    pub fn row(&self, u: u32) -> (&[u32], &[f32]) {
        let lo = self.indptr[u as usize];
        let hi = self.indptr[u as usize + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Entries of one row as an iterator of [`Entry`].
    pub fn row_entries(&self, u: u32) -> impl Iterator<Item = Entry> + '_ {
        let (idx, val) = self.row(u);
        idx.iter()
            .zip(val.iter())
            .map(move |(&v, &r)| Entry { u, v, r })
    }

    /// Number of entries in one row.
    pub fn row_nnz(&self, u: u32) -> usize {
        self.indptr[u as usize + 1] - self.indptr[u as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo() -> CooMatrix {
        let mut m = CooMatrix::new(3, 4);
        m.push(0, 1, 1.0).unwrap();
        m.push(0, 3, 2.0).unwrap();
        m.push(2, 0, 3.0).unwrap();
        m.push(1, 2, 4.0).unwrap();
        m
    }

    #[test]
    fn from_coo_rows() {
        let c = CsrMatrix::from_coo(&coo());
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.row(0), (&[1u32, 3][..], &[1.0f32, 2.0][..]));
        assert_eq!(c.row(1), (&[2u32][..], &[4.0f32][..]));
        assert_eq!(c.row(2), (&[0u32][..], &[3.0f32][..]));
        assert_eq!(c.row_nnz(0), 2);
    }

    #[test]
    fn transpose_roundtrip() {
        let c = CsrMatrix::from_coo(&coo());
        let t = c.transpose();
        assert_eq!(t.nrows(), 4);
        assert_eq!(t.ncols(), 3);
        assert_eq!(t.nnz(), 4);
        // (0,1,1.0) becomes (1,0,1.0)
        assert_eq!(t.row(1), (&[0u32][..], &[1.0f32][..]));
        let tt = t.transpose();
        for u in 0..3u32 {
            assert_eq!(tt.row(u), c.row(u));
        }
    }

    #[test]
    fn row_entries_iter() {
        let c = CsrMatrix::from_coo(&coo());
        let es: Vec<Entry> = c.row_entries(0).collect();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].u, 0);
        assert_eq!(es[0].v, 1);
    }

    #[test]
    fn empty_rows() {
        let m = CooMatrix::new(3, 3);
        let c = CsrMatrix::from_coo(&m);
        for u in 0..3 {
            assert_eq!(c.row_nnz(u), 0);
        }
    }
}

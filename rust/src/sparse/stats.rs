//! Marginal-distribution statistics for HDS matrices.
//!
//! The load-balancing study (paper §III-B, our ablation A2) is about *skew*:
//! how unevenly instances distribute over rows/columns and over blocks.
//! These are the measures the bench harness reports.

/// Summary of a count distribution (e.g. instances per row block).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CountStats {
    /// Number of buckets.
    pub n: usize,
    /// Smallest count.
    pub min: u64,
    /// Largest count.
    pub max: u64,
    /// Mean count.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// max/mean — the "last reducer" factor (1.0 = perfectly balanced).
    pub imbalance: f64,
    /// Gini coefficient of the counts (0 = equal, →1 = concentrated).
    pub gini: f64,
}

/// Compute [`CountStats`] over a slice of bucket counts.
pub fn count_stats(counts: &[u64]) -> CountStats {
    assert!(!counts.is_empty(), "count_stats over empty slice");
    let n = counts.len();
    let total: u64 = counts.iter().sum();
    let mean = total as f64 / n as f64;
    let min = *counts.iter().min().unwrap();
    let max = *counts.iter().max().unwrap();
    let var = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    let imbalance = if mean > 0.0 { max as f64 / mean } else { 1.0 };
    CountStats {
        n,
        min,
        max,
        mean,
        std: var.sqrt(),
        imbalance,
        gini: gini(counts),
    }
}

/// Gini coefficient of non-negative counts.
pub fn gini(counts: &[u64]) -> f64 {
    let n = counts.len();
    if n == 0 {
        return 0.0;
    }
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut sorted: Vec<u64> = counts.to_vec();
    sorted.sort_unstable();
    // G = (2 Σ_i i·x_i) / (n Σ x) − (n+1)/n  with i starting at 1
    let weighted: f64 = sorted
        .iter()
        .enumerate()
        .map(|(i, &x)| (i as f64 + 1.0) * x as f64)
        .sum();
    (2.0 * weighted) / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
}

/// Convert u32 counts to the u64 the stats take.
pub fn widen(counts: &[u32]) -> Vec<u64> {
    counts.iter().map(|&c| c as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_counts_are_balanced() {
        let s = count_stats(&[10, 10, 10, 10]);
        assert_eq!(s.min, 10);
        assert_eq!(s.max, 10);
        assert!((s.imbalance - 1.0).abs() < 1e-12);
        assert!(s.gini.abs() < 1e-12);
        assert!(s.std.abs() < 1e-12);
    }

    #[test]
    fn skewed_counts_detected() {
        let s = count_stats(&[0, 0, 0, 100]);
        assert_eq!(s.max, 100);
        assert!((s.imbalance - 4.0).abs() < 1e-12);
        assert!(s.gini > 0.7);
    }

    #[test]
    fn gini_empty_and_zero() {
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0, 0, 0]), 0.0);
    }

    #[test]
    fn gini_monotone_in_skew() {
        let even = gini(&[25, 25, 25, 25]);
        let mild = gini(&[10, 20, 30, 40]);
        let harsh = gini(&[1, 1, 1, 97]);
        assert!(even < mild && mild < harsh);
    }

    #[test]
    fn property_imbalance_at_least_one() {
        crate::proptest_lite::check(
            "imbalance >= 1 when total > 0",
            128,
            |g| {
                let n = g.usize_in(1, 50);
                g.vec(n, |g| g.u64(1000))
            },
            |counts| {
                let total: u64 = counts.iter().sum();
                total == 0 || count_stats(counts).imbalance >= 1.0 - 1e-12
            },
        );
    }

    #[test]
    fn property_gini_in_unit_interval() {
        crate::proptest_lite::check(
            "gini ∈ [0,1)",
            128,
            |g| {
                let n = g.usize_in(1, 60);
                g.vec(n, |g| g.u64(10_000))
            },
            |counts| {
                let g = gini(counts);
                (0.0..1.0).contains(&g) || g.abs() < 1e-12
            },
        );
    }
}

//! Coordinate-format sparse matrix (the HDS matrix ingestion format).

use crate::Result;
use anyhow::{bail, ensure};

/// One known instance r_uv ∈ Ω.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    /// Row (node u ∈ U).
    pub u: u32,
    /// Column (node v ∈ V).
    pub v: u32,
    /// Interaction weight r_uv.
    pub r: f32,
}

/// An HDS matrix stored as a coordinate list.
#[derive(Clone, Debug, Default)]
pub struct CooMatrix {
    nrows: u32,
    ncols: u32,
    entries: Vec<Entry>,
}

impl CooMatrix {
    /// Empty matrix with fixed logical dimensions.
    pub fn new(nrows: u32, ncols: u32) -> Self {
        CooMatrix { nrows, ncols, entries: Vec::new() }
    }

    /// Build from triplets, validating indices.
    pub fn from_entries(nrows: u32, ncols: u32, entries: Vec<Entry>) -> Result<Self> {
        for e in &entries {
            ensure!(
                e.u < nrows && e.v < ncols,
                "entry ({}, {}) out of bounds for {}x{}",
                e.u,
                e.v,
                nrows,
                ncols
            );
            ensure!(e.r.is_finite(), "non-finite rating at ({}, {})", e.u, e.v);
        }
        Ok(CooMatrix { nrows, ncols, entries })
    }

    /// Append one instance.
    pub fn push(&mut self, u: u32, v: u32, r: f32) -> Result<()> {
        if u >= self.nrows || v >= self.ncols {
            bail!("entry ({u}, {v}) out of bounds for {}x{}", self.nrows, self.ncols);
        }
        self.entries.push(Entry { u, v, r });
        Ok(())
    }

    /// Number of rows |U|.
    pub fn nrows(&self) -> u32 {
        self.nrows
    }

    /// Number of columns |V|.
    pub fn ncols(&self) -> u32 {
        self.ncols
    }

    /// |Ω| — number of known instances.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Known instances.
    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    /// Mutable access (e.g. for shuffling the training order).
    pub fn entries_mut(&mut self) -> &mut [Entry] {
        &mut self.entries
    }

    /// Fraction of cells observed: |Ω| / (|U|·|V|).
    pub fn density(&self) -> f64 {
        self.entries.len() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// Instances per row (the row marginal ⟨R_{u,:}⟩ per node).
    pub fn row_counts(&self) -> Vec<u32> {
        let mut c = vec![0u32; self.nrows as usize];
        for e in &self.entries {
            c[e.u as usize] += 1;
        }
        c
    }

    /// Instances per column.
    pub fn col_counts(&self) -> Vec<u32> {
        let mut c = vec![0u32; self.ncols as usize];
        for e in &self.entries {
            c[e.v as usize] += 1;
        }
        c
    }

    /// Sort entries row-major (u, then v) — canonical order for CSR build.
    pub fn sort_row_major(&mut self) {
        self.entries.sort_unstable_by(|a, b| (a.u, a.v).cmp(&(b.u, b.v)));
    }

    /// Drop duplicate (u,v) pairs, keeping the last occurrence.
    /// Returns the number of duplicates removed.
    pub fn dedup(&mut self) -> usize {
        dedup_keep_last(&mut self.entries)
    }

    /// Mean rating over Ω (0 if empty).
    pub fn mean_rating(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.iter().map(|e| e.r as f64).sum::<f64>() / self.entries.len() as f64
    }

    /// Min/max rating over Ω.
    pub fn rating_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for e in &self.entries {
            lo = lo.min(e.r);
            hi = hi.max(e.r);
        }
        (lo, hi)
    }

    /// Partition entries into two matrices by predicate (true → first).
    pub fn partition_by(&self, mut pred: impl FnMut(&Entry) -> bool) -> (CooMatrix, CooMatrix) {
        let mut a = CooMatrix::new(self.nrows, self.ncols);
        let mut b = CooMatrix::new(self.nrows, self.ncols);
        for e in &self.entries {
            if pred(e) {
                a.entries.push(*e);
            } else {
                b.entries.push(*e);
            }
        }
        (a, b)
    }
}

/// Sort `entries` into canonical row-major `(u, v)` order and drop duplicate
/// pairs, keeping the **last occurrence in input order** (stable sort, so
/// equal keys preserve input order; then reverse → dedup-first → reverse).
/// Returns the number of duplicates removed.
///
/// This is the single dedup definition both [`CooMatrix::dedup`] (the text
/// loader) and the pack-time shard finalizer use — out-of-core vs in-memory
/// bit-parity depends on them agreeing on survivor choice and final order.
pub fn dedup_keep_last(entries: &mut Vec<Entry>) -> usize {
    let before = entries.len();
    entries.sort_by(|a, b| (a.u, a.v).cmp(&(b.u, b.v)));
    entries.reverse();
    entries.dedup_by(|a, b| a.u == b.u && a.v == b.v);
    entries.reverse();
    before - entries.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CooMatrix {
        let mut m = CooMatrix::new(4, 3);
        m.push(0, 0, 5.0).unwrap();
        m.push(1, 2, 3.0).unwrap();
        m.push(3, 1, 1.0).unwrap();
        m.push(1, 0, 4.0).unwrap();
        m
    }

    #[test]
    fn push_and_counts() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_counts(), vec![1, 2, 0, 1]);
        assert_eq!(m.col_counts(), vec![2, 1, 1]);
    }

    #[test]
    fn push_out_of_bounds_fails() {
        let mut m = CooMatrix::new(2, 2);
        assert!(m.push(2, 0, 1.0).is_err());
        assert!(m.push(0, 2, 1.0).is_err());
    }

    #[test]
    fn from_entries_validates() {
        let bad = vec![Entry { u: 9, v: 0, r: 1.0 }];
        assert!(CooMatrix::from_entries(2, 2, bad).is_err());
        let nan = vec![Entry { u: 0, v: 0, r: f32::NAN }];
        assert!(CooMatrix::from_entries(2, 2, nan).is_err());
    }

    #[test]
    fn density() {
        let m = sample();
        assert!((m.density() - 4.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn sort_row_major_orders() {
        let mut m = sample();
        m.sort_row_major();
        let keys: Vec<(u32, u32)> = m.entries().iter().map(|e| (e.u, e.v)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn dedup_keeps_last() {
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 0, 1.0).unwrap();
        m.push(0, 0, 2.0).unwrap();
        m.push(1, 1, 3.0).unwrap();
        assert_eq!(m.dedup(), 1);
        assert_eq!(m.nnz(), 2);
        let e = m.entries().iter().find(|e| e.u == 0 && e.v == 0).unwrap();
        assert_eq!(e.r, 2.0);
    }

    #[test]
    fn dedup_keep_last_is_stable_under_interleaving() {
        // Duplicates separated by unrelated entries: the *last* occurrence
        // in input order must survive (requires the stable sort).
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 0, 1.0).unwrap();
        m.push(1, 0, 9.0).unwrap();
        m.push(0, 0, 2.0).unwrap();
        m.push(2, 2, 7.0).unwrap();
        m.push(0, 0, 3.0).unwrap();
        assert_eq!(m.dedup(), 2);
        let e = m.entries().iter().find(|e| e.u == 0 && e.v == 0).unwrap();
        assert_eq!(e.r, 3.0, "keep-last must pick the final occurrence");
        // Result is in canonical row-major order.
        let keys: Vec<(u32, u32)> = m.entries().iter().map(|e| (e.u, e.v)).collect();
        assert_eq!(keys, vec![(0, 0), (1, 0), (2, 2)]);
    }

    #[test]
    fn mean_and_range() {
        let m = sample();
        assert!((m.mean_rating() - 3.25).abs() < 1e-9);
        assert_eq!(m.rating_range(), (1.0, 5.0));
    }

    #[test]
    fn partition_by_splits_all() {
        let m = sample();
        let (a, b) = m.partition_by(|e| e.u == 1);
        assert_eq!(a.nnz(), 2);
        assert_eq!(b.nnz(), 2);
        assert_eq!(a.nnz() + b.nnz(), m.nnz());
    }
}

"""AOT pipeline tests: lowering produces loadable HLO text + sane manifest."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.emit(out, b=32, d=4, u=16, v=16)
    return out


def test_all_artifacts_written(small_artifacts):
    files = sorted(os.listdir(small_artifacts))
    assert "manifest.toml" in files
    assert any(f.startswith("predict_") for f in files)
    assert any(f.startswith("eval_") for f in files)
    assert any(f.startswith("loss_") for f in files)
    assert any(f.startswith("update_") for f in files)


def test_hlo_text_is_parseable_header(small_artifacts):
    for f in os.listdir(small_artifacts):
        if f.endswith(".hlo.txt"):
            text = open(os.path.join(small_artifacts, f)).read()
            assert text.startswith("HloModule"), f
            assert "ENTRY" in text, f


def test_manifest_contents(small_artifacts):
    text = open(os.path.join(small_artifacts, "manifest.toml")).read()
    assert "[shapes]" in text
    assert "b = 32" in text and "d = 4" in text
    for name in ("predict", "eval", "loss", "update"):
        assert f"[artifact.{name}]" in text


def test_lowered_predict_runs_and_matches(small_artifacts):
    """Round-trip the lowered HLO through jax's own runtime for numerics."""
    from jax._src.lib import xla_client as xc
    import jax

    fn, specs = model.make_specs(b=8, d=4)["predict"]
    text = aot.to_hlo_text(fn, specs)
    assert "HloModule" in text
    # Execute the original fn and compare with a hand dot.
    mu = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) * 0.1
    nv = jnp.ones((8, 4), jnp.float32)
    (got,) = fn(mu, nv)
    np.testing.assert_allclose(got, np.asarray(mu).sum(axis=1), rtol=1e-6)


def test_update_artifact_has_eleven_inputs(small_artifacts):
    text = open(os.path.join(small_artifacts, "manifest.toml")).read()
    sec = text.split("[artifact.update]")[1]
    assert "inputs = 11" in sec

"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the compute stack: every kernel is
pinned to its oracle across hypothesis-generated shapes, values, and tile
sizes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import nag, predict, ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- rowwise_dot
class TestRowwiseDot:
    @pytest.mark.parametrize("b,d", [(1, 1), (4, 8), (256, 16), (512, 64), (1000, 3)])
    def test_matches_ref(self, b, d):
        k1, k2 = _keys(b * 31 + d, 2)
        mu, nv = _rand(k1, b, d), _rand(k2, b, d)
        got = predict.rowwise_dot(mu, nv)
        np.testing.assert_allclose(got, ref.rowwise_dot(mu, nv), rtol=1e-5, atol=1e-5)

    @hypothesis.given(
        b=st.integers(1, 300),
        d=st.integers(1, 40),
        tile=st.integers(1, 128),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes_tiles(self, b, d, tile, seed):
        k1, k2 = _keys(seed, 2)
        mu, nv = _rand(k1, b, d), _rand(k2, b, d)
        got = predict.rowwise_dot(mu, nv, tile_b=tile)
        np.testing.assert_allclose(got, ref.rowwise_dot(mu, nv), rtol=1e-5, atol=1e-5)

    def test_zero_inputs(self):
        z = jnp.zeros((8, 4), jnp.float32)
        assert np.all(np.asarray(predict.rowwise_dot(z, z)) == 0.0)

    def test_orthogonal_rows(self):
        mu = jnp.eye(4, dtype=jnp.float32)
        nv = jnp.roll(jnp.eye(4, dtype=jnp.float32), 1, axis=0)
        np.testing.assert_allclose(predict.rowwise_dot(mu, nv), jnp.zeros(4), atol=0)

    def test_tile_independence(self):
        k1, k2 = _keys(7, 2)
        mu, nv = _rand(k1, 96, 16), _rand(k2, 96, 16)
        a = predict.rowwise_dot(mu, nv, tile_b=96)
        b = predict.rowwise_dot(mu, nv, tile_b=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------- predict_error
class TestPredictError:
    @pytest.mark.parametrize("b,d", [(2, 2), (64, 16), (512, 16), (4096, 16)])
    def test_matches_ref(self, b, d):
        k1, k2, k3 = _keys(b + d, 3)
        mu, nv = _rand(k1, b, d), _rand(k2, b, d)
        r = _rand(k3, b)
        got = predict.predict_error(mu, nv, r)
        np.testing.assert_allclose(
            got, ref.predict_error(mu, nv, r), rtol=1e-5, atol=1e-5
        )

    @hypothesis.given(
        b=st.integers(1, 257),
        d=st.integers(1, 33),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, b, d, seed):
        k1, k2, k3 = _keys(seed, 3)
        mu, nv, r = _rand(k1, b, d), _rand(k2, b, d), _rand(k3, b)
        got = predict.predict_error(mu, nv, r)
        np.testing.assert_allclose(
            got, ref.predict_error(mu, nv, r), rtol=1e-5, atol=1e-5
        )

    def test_perfect_prediction_gives_zero_error(self):
        mu = jnp.ones((16, 4), jnp.float32)
        nv = jnp.ones((16, 4), jnp.float32)
        r = jnp.full((16,), 4.0, jnp.float32)
        np.testing.assert_allclose(predict.predict_error(mu, nv, r), 0.0, atol=1e-6)


# -------------------------------------------------------------- nag_gradients
class TestNagGradients:
    @pytest.mark.parametrize("b,d", [(1, 1), (32, 8), (512, 16)])
    @pytest.mark.parametrize("lam", [0.0, 0.03, 0.5])
    def test_matches_ref(self, b, d, lam):
        k1, k2, k3 = _keys(b * 17 + d, 3)
        mu, nv, r = _rand(k1, b, d), _rand(k2, b, d), _rand(k3, b)
        e, gm, gn = nag.nag_gradients(mu, nv, r, lam)
        re, rgm, rgn = ref.nag_gradients(mu, nv, r, lam)
        np.testing.assert_allclose(e, re, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gm, rgm, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gn, rgn, rtol=1e-5, atol=1e-5)

    @hypothesis.given(
        b=st.integers(1, 130),
        d=st.integers(1, 24),
        lam=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, b, d, lam, seed):
        k1, k2, k3 = _keys(seed, 3)
        mu, nv, r = _rand(k1, b, d), _rand(k2, b, d), _rand(k3, b)
        e, gm, gn = nag.nag_gradients(mu, nv, r, lam)
        re, rgm, rgn = ref.nag_gradients(mu, nv, r, lam)
        np.testing.assert_allclose(e, re, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gm, rgm, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gn, rgn, rtol=1e-4, atol=1e-4)

    def test_gradient_is_descent_direction(self):
        """Following g with small η must reduce squared error (λ=0)."""
        k1, k2, k3 = _keys(3, 3)
        mu, nv, r = _rand(k1, 64, 8), _rand(k2, 64, 8), _rand(k3, 64)
        e, gm, gn = nag.nag_gradients(mu, nv, r, 0.0)
        eta = 1e-3
        mu2, nv2 = mu + eta * gm, nv + eta * gn
        e2 = ref.predict_error(mu2, nv2, r)
        assert float(jnp.sum(e2 * e2)) < float(jnp.sum(e * e))

    def test_lambda_zero_matches_unregularized(self):
        k1, k2, k3 = _keys(11, 3)
        mu, nv, r = _rand(k1, 32, 4), _rand(k2, 32, 4), _rand(k3, 32)
        e, gm, gn = nag.nag_gradients(mu, nv, r, 0.0)
        np.testing.assert_allclose(gm, np.asarray(e)[:, None] * nv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gn, np.asarray(e)[:, None] * mu, rtol=1e-5, atol=1e-6)

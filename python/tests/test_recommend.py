"""L1 correctness for the top-N scoring kernel."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import recommend, ref

hypothesis.settings.register_profile(
    "recommend", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("recommend")


def _rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


class TestScoreAllItems:
    @pytest.mark.parametrize("v,d", [(1, 1), (8, 4), (1024, 16), (1000, 7)])
    def test_matches_ref(self, v, d):
        mu = _rand(v + d, d)
        n = _rand(v * 31 + d, v, d)
        got = recommend.score_all_items(mu, n)
        np.testing.assert_allclose(got, ref.score_all_items(mu, n), rtol=1e-5, atol=1e-5)

    @hypothesis.given(
        v=st.integers(1, 400),
        d=st.integers(1, 32),
        tile=st.integers(1, 256),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes_tiles(self, v, d, tile, seed):
        mu = _rand(seed, d)
        n = _rand(seed + 1, v, d)
        got = recommend.score_all_items(mu, n, tile_v=tile)
        np.testing.assert_allclose(got, ref.score_all_items(mu, n), rtol=1e-4, atol=1e-4)

    def test_identity_items_echo_user_row(self):
        d = 4
        mu = jnp.arange(d, dtype=jnp.float32)
        n = jnp.eye(d, dtype=jnp.float32)
        got = recommend.score_all_items(mu, n)
        np.testing.assert_allclose(got, mu, atol=0)

    def test_topk_ordering_preserved(self):
        mu = jnp.ones(8, dtype=jnp.float32)
        n = jnp.stack([jnp.full(8, float(i)) for i in range(32)])
        scores = np.asarray(recommend.score_all_items(mu, n))
        top = np.argsort(-scores)[:5]
        assert list(top) == [31, 30, 29, 28, 27]

"""L2 correctness: model-level batched functions (shapes, masking, update)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("model")


def _mk(seed, b=64, d=8, u=32, v=24):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    m = jax.random.normal(ks[0], (u, d), jnp.float32) * 0.1
    n = jax.random.normal(ks[1], (v, d), jnp.float32) * 0.1
    phi = jnp.zeros((u, d), jnp.float32)
    psi = jnp.zeros((v, d), jnp.float32)
    uidx = jax.random.randint(ks[2], (b,), 0, u)
    vidx = jax.random.randint(ks[3], (b,), 0, v)
    r = jax.random.uniform(ks[4], (b,), jnp.float32, 1.0, 5.0)
    mask = jnp.ones((b,), jnp.float32)
    return m, n, phi, psi, uidx, vidx, r, mask


class TestEvalBatch:
    def test_matches_numpy(self):
        m, n, _, _, uidx, vidx, r, mask = _mk(0)
        sse, sae, cnt = model.eval_batch(m[uidx], n[vidx], r, mask)
        e = np.asarray(ref.predict_error(m[uidx], n[vidx], r))
        np.testing.assert_allclose(sse, np.sum(e * e), rtol=1e-5)
        np.testing.assert_allclose(sae, np.sum(np.abs(e)), rtol=1e-5)
        assert float(cnt) == 64.0

    def test_mask_excludes_lanes(self):
        m, n, _, _, uidx, vidx, r, mask = _mk(1)
        mask = mask.at[::2].set(0.0)
        sse, sae, cnt = model.eval_batch(m[uidx], n[vidx], r, mask)
        e = np.asarray(ref.predict_error(m[uidx], n[vidx], r)) * np.asarray(mask)
        np.testing.assert_allclose(sse, np.sum(e * e), rtol=1e-5)
        assert float(cnt) == 32.0

    def test_all_masked_gives_zero(self):
        m, n, _, _, uidx, vidx, r, mask = _mk(2)
        sse, sae, cnt = model.eval_batch(m[uidx], n[vidx], r, mask * 0.0)
        assert float(sse) == 0.0 and float(sae) == 0.0 and float(cnt) == 0.0


class TestLossBatch:
    def test_matches_eq1(self):
        m, n, _, _, uidx, vidx, r, mask = _mk(3)
        lam = 0.05
        (loss,) = model.loss_batch(m[uidx], n[vidx], r, mask, jnp.float32(lam))
        mu, nv = np.asarray(m)[np.asarray(uidx)], np.asarray(n)[np.asarray(vidx)]
        e = np.asarray(r) - np.sum(mu * nv, axis=-1)
        want = 0.5 * np.sum(e * e + lam * (np.sum(mu * mu, -1) + np.sum(nv * nv, -1)))
        np.testing.assert_allclose(float(loss), want, rtol=1e-5)

    def test_loss_nonnegative(self):
        m, n, _, _, uidx, vidx, r, mask = _mk(4)
        (loss,) = model.loss_batch(m[uidx], n[vidx], r, mask, jnp.float32(0.1))
        assert float(loss) >= 0.0


class TestBlockUpdate:
    def test_reduces_training_error(self):
        # Per-row effective step is η × (instances per row ≈ B/U), so keep η
        # small enough that aggregated-minibatch NAG stays in the stable regime.
        m, n, phi, psi, uidx, vidx, r, mask = _mk(5, b=256, u=16, v=12)
        args = dict(eta=jnp.float32(2e-3), lam=jnp.float32(0.01), gamma=jnp.float32(0.9))
        sse0 = float(model.eval_batch(m[uidx], n[vidx], r, mask)[0])
        for _ in range(100):
            m, n, phi, psi = model.block_update(
                m, n, phi, psi, uidx, vidx, r, mask, **args
            )
        sse1 = float(model.eval_batch(m[uidx], n[vidx], r, mask)[0])
        assert sse1 < 0.5 * sse0

    def test_untouched_rows_unchanged(self):
        m, n, phi, psi, uidx, vidx, r, mask = _mk(6, b=8, u=64, v=64)
        m2, n2, phi2, psi2 = model.block_update(
            m, n, phi, psi, uidx, vidx, r, mask,
            jnp.float32(0.1), jnp.float32(0.1), jnp.float32(0.9),
        )
        touched_u = set(np.asarray(uidx).tolist())
        for row in range(64):
            if row not in touched_u:
                np.testing.assert_array_equal(np.asarray(m2[row]), np.asarray(m[row]))
                np.testing.assert_array_equal(np.asarray(phi2[row]), np.asarray(phi[row]))

    def test_masked_batch_is_identity(self):
        m, n, phi, psi, uidx, vidx, r, mask = _mk(7)
        m2, n2, phi2, psi2 = model.block_update(
            m, n, phi, psi, uidx, vidx, r, mask * 0.0,
            jnp.float32(0.1), jnp.float32(0.1), jnp.float32(0.9),
        )
        np.testing.assert_array_equal(np.asarray(m2), np.asarray(m))
        np.testing.assert_array_equal(np.asarray(n2), np.asarray(n))

    def test_single_instance_matches_per_instance_nag(self):
        """B=1 mini-batch must equal the paper's per-instance rule exactly."""
        m, n, phi, psi, *_ = _mk(8, b=1, u=4, v=4)
        phi = phi + 0.01
        psi = psi + 0.02
        uidx = jnp.array([2], jnp.int32)
        vidx = jnp.array([1], jnp.int32)
        r = jnp.array([3.5], jnp.float32)
        mask = jnp.ones((1,), jnp.float32)
        eta, lam, gamma = 0.01, 0.05, 0.9
        m2, n2, phi2, psi2 = model.block_update(
            m, n, phi, psi, uidx, vidx, r, mask,
            jnp.float32(eta), jnp.float32(lam), jnp.float32(gamma),
        )
        mu2, nv2, p2, q2 = ref.nag_step(
            m[uidx], n[vidx], phi[uidx], psi[vidx], r, eta, lam, gamma
        )
        np.testing.assert_allclose(np.asarray(m2[2]), np.asarray(mu2[0]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(n2[1]), np.asarray(nv2[0]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(phi2[2]), np.asarray(p2[0]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(psi2[1]), np.asarray(q2[0]), rtol=1e-5)

    @hypothesis.given(
        b=st.integers(1, 64),
        u=st.integers(2, 32),
        v=st.integers(2, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shapes_preserved(self, b, u, v, seed):
        m, n, phi, psi, uidx, vidx, r, mask = _mk(seed % 1000, b=b, d=4, u=u, v=v)
        outs = model.block_update(
            m, n, phi, psi, uidx, vidx, r, mask,
            jnp.float32(0.01), jnp.float32(0.01), jnp.float32(0.5),
        )
        for got, want in zip(outs, (m, n, phi, psi)):
            assert got.shape == want.shape and got.dtype == want.dtype
            assert bool(jnp.all(jnp.isfinite(got)))


class TestGammaZeroIsPlainSGDMinibatch:
    def test_gamma0_equals_sgd(self):
        """γ=0 collapses NAG to plain SGD (Eq. 3) for non-repeating rows."""
        d = 4
        m = jnp.ones((4, d), jnp.float32) * 0.3
        n = jnp.ones((4, d), jnp.float32) * 0.2
        phi = jnp.zeros_like(m)
        psi = jnp.zeros_like(n)
        uidx = jnp.array([0, 1], jnp.int32)
        vidx = jnp.array([2, 3], jnp.int32)
        r = jnp.array([4.0, 2.0], jnp.float32)
        mask = jnp.ones((2,), jnp.float32)
        eta, lam = 0.1, 0.02
        m2, n2, _, _ = model.block_update(
            m, n, phi, psi, uidx, vidx, r, mask,
            jnp.float32(eta), jnp.float32(lam), jnp.float32(0.0),
        )
        mu2, nv2 = ref.sgd_step(m[uidx], n[vidx], r, eta, lam)
        np.testing.assert_allclose(np.asarray(m2[:2]), np.asarray(mu2), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(n2[2:]), np.asarray(nv2), rtol=1e-5)

"""Layer-2 JAX model: batched LR-model math built on the Layer-1 kernels.

Every public function here is AOT-lowered by ``aot.py`` to an HLO-text
artifact with *static* shapes (batch B, feature dim D, padded row counts
U, V) and executed from the Rust coordinator via PJRT. Padding protocol:
callers pad batches to B with ``mask = 0`` entries whose indices point at
row 0; masked lanes contribute nothing to sums and updates.

Functions
---------
predict_batch(mu, nv)                      -> (r̂,)
eval_batch(mu, nv, r, mask)                -> (sse, sae, cnt)
loss_batch(mu, nv, r, mask, lam)           -> (ε,)
block_update(M, N, phi, psi, uidx, vidx,
             r, mask, eta, lam, gamma)     -> (M', N', phi', psi')
"""

import jax
import jax.numpy as jnp

from .kernels import nag_gradients, predict_error, rowwise_dot, score_all_items

# Default AOT shapes; aot.py may emit additional variants.
DEFAULT_B = 4096
DEFAULT_D = 16
DEFAULT_U = 8192
DEFAULT_V = 8192
DEFAULT_K = 8  # scan steps fused into one `epoch_update` call


def predict_batch(mu, nv):
    """Batched prediction r̂[b] = ⟨mu[b,:], nv[b,:]⟩ (serving hot path)."""
    return (rowwise_dot(mu, nv),)


def eval_batch(mu, nv, r, mask):
    """Masked error sums for RMSE/MAE accumulation on the test set.

    Returns (Σ mask·e², Σ mask·|e|, Σ mask) as f32 scalars; the Rust side
    accumulates across batches and takes sqrt/mean once per epoch.
    """
    e = predict_error(mu, nv, r) * mask
    return jnp.sum(e * e), jnp.sum(jnp.abs(e)), jnp.sum(mask)


def loss_batch(mu, nv, r, mask, lam):
    """Regularized loss ε (paper Eq. 1) restricted to one batch of instances."""
    e = predict_error(mu, nv, r)
    reg = jnp.sum(mu * mu, axis=-1) + jnp.sum(nv * nv, axis=-1)
    return (0.5 * jnp.sum(mask * (e * e + lam * reg)),)


def block_update(m, n, phi, psi, uidx, vidx, r, mask, eta, lam, gamma):
    """One mini-batch NAG step (paper Eqs. 4–5) over padded factor matrices.

    Mini-batch semantics: gradients of all instances in the batch are
    evaluated at the same look-ahead point and aggregated per row with a
    segment sum; momentum decays once per touched row. This is the batched
    adaptation of the paper's per-instance rule (DESIGN.md §6).

    Args:
      m:    f32[U, D] user factors (padded).
      n:    f32[V, D] item factors (padded).
      phi:  f32[U, D] user momentum (paper φ).
      psi:  f32[V, D] item momentum (paper ψ).
      uidx: i32[B] user row per instance.
      vidx: i32[B] item row per instance.
      r:    f32[B] ratings.
      mask: f32[B] 1.0 for live lanes, 0.0 for padding.
      eta, lam, gamma: f32[] hyperparameters η, λ, γ.

    Returns:
      (m', n', phi', psi') with the same shapes.
    """
    u_rows, _ = m.shape
    v_rows, _ = n.shape

    # Look-ahead gather: m̂_u = m_u + γφ_u (Eq. 4), n̂_v = n_v + γψ_v (Eq. 5).
    mu_hat = m[uidx] + gamma * phi[uidx]
    nv_hat = n[vidx] + gamma * psi[vidx]

    # Fused Pallas core: e, g_m = e·n̂ − λm̂, g_n = e·m̂ − λn̂.
    _, g_m, g_n = nag_gradients(mu_hat, nv_hat, r, lam)
    g_m = g_m * mask[:, None]
    g_n = g_n * mask[:, None]

    # Per-row aggregation of instance gradients.
    gm_rows = jax.ops.segment_sum(g_m, uidx, num_segments=u_rows)
    gn_rows = jax.ops.segment_sum(g_n, vidx, num_segments=v_rows)
    touched_u = (jax.ops.segment_sum(mask, uidx, num_segments=u_rows) > 0)[:, None]
    touched_v = (jax.ops.segment_sum(mask, vidx, num_segments=v_rows) > 0)[:, None]

    # Momentum + parameter update for touched rows only.
    phi2 = jnp.where(touched_u, gamma * phi + eta * gm_rows, phi)
    psi2 = jnp.where(touched_v, gamma * psi + eta * gn_rows, psi)
    m2 = jnp.where(touched_u, m + phi2, m)
    n2 = jnp.where(touched_v, n + psi2, n)
    return m2, n2, phi2, psi2


def epoch_update(m, n, phi, psi, uidx, vidx, r, mask, eta, lam, gamma):
    """K chained mini-batch NAG steps in one executable (lax.scan).

    §Perf: one PJRT call covers K batches, so the U×D/V×D factor transfers
    across the host boundary are amortized K× (the xla crate cannot keep
    buffers device-resident between calls — its PJRT wrapper always returns
    a single tuple buffer).

    Index/rating/mask arrays carry a leading K axis.
    """

    def body(carry, xs):
        cm, cn, cphi, cpsi = carry
        ui, vi, rr, mm = xs
        out = block_update(cm, cn, cphi, cpsi, ui, vi, rr, mm, eta, lam, gamma)
        return out, ()

    (m2, n2, phi2, psi2), _ = jax.lax.scan(body, (m, n, phi, psi), (uidx, vidx, r, mask))
    return m2, n2, phi2, psi2


def recommend(mu, n):
    """Scores of one user row against the padded item matrix (top-N path)."""
    return (score_all_items(mu, n),)


def make_specs(b=DEFAULT_B, d=DEFAULT_D, u=DEFAULT_U, v=DEFAULT_V, k=DEFAULT_K):
    """ShapeDtypeStructs for each AOT entry point, keyed by artifact name."""
    f32 = jnp.float32
    i32 = jnp.int32
    mat = lambda r, c: jax.ShapeDtypeStruct((r, c), f32)  # noqa: E731
    vec = lambda k, t=f32: jax.ShapeDtypeStruct((k,), t)  # noqa: E731
    scal = jax.ShapeDtypeStruct((), f32)
    return {
        "predict": (predict_batch, [mat(b, d), mat(b, d)]),
        "eval": (eval_batch, [mat(b, d), mat(b, d), vec(b), vec(b)]),
        "loss": (loss_batch, [mat(b, d), mat(b, d), vec(b), vec(b), scal]),
        "recommend": (recommend, [jax.ShapeDtypeStruct((d,), f32), mat(v, d)]),
        "update": (
            block_update,
            [
                mat(u, d), mat(v, d), mat(u, d), mat(v, d),
                vec(b, i32), vec(b, i32), vec(b), vec(b),
                scal, scal, scal,
            ],
        ),
        "update_scan": (
            epoch_update,
            [
                mat(u, d), mat(v, d), mat(u, d), mat(v, d),
                jax.ShapeDtypeStruct((k, b), i32),
                jax.ShapeDtypeStruct((k, b), i32),
                jax.ShapeDtypeStruct((k, b), f32),
                jax.ShapeDtypeStruct((k, b), f32),
                scal, scal, scal,
            ],
        ),
    }

"""Top-N scoring Pallas kernel: one user's factor row against ALL items.

The recommendation serving path (the intro's motivating application) needs
scores[v] = ⟨m_u, n_v⟩ for every item v. As a matvec over N^{V×D} it is
memory-bound (reads V·D floats once); the kernel tiles V into (TV, D) VMEM
blocks and broadcasts the user row to every tile — each HBM byte is touched
exactly once, which is the roofline for this op.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .predict import _tile

# 1024 items × 64 dims × 4 B = 256 KiB per tile.
DEFAULT_TILE_V = 1024


def _score_kernel(mu_ref, n_ref, out_ref):
    """out[v] = Σ_d mu[0,d] · n[v,d] for one (TV, D) tile of N."""
    out_ref[...] = jnp.sum(mu_ref[...] * n_ref[...], axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_v",))
def score_all_items(mu, n, *, tile_v: int = DEFAULT_TILE_V):
    """Scores of one user against all items.

    Args:
      mu: f32[D] the user's factor row.
      n:  f32[V, D] the full item-factor matrix.
      tile_v: items per VMEM tile.

    Returns:
      f32[V] scores.
    """
    v, d = n.shape
    tv = _tile(v, tile_v)
    grid = (v // tv,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # user row broadcast
            pl.BlockSpec((tv, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), n.dtype),
        interpret=True,
    )(mu.reshape(1, d), n)

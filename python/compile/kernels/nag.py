"""Fused NAG gradient core (paper Eqs. 4–5) as a Pallas kernel.

Given *look-ahead* factor rows m̂_u = m_u + γφ_u and n̂_v = n_v + γψ_v
(the gather and look-ahead shift live in Layer 2), ratings r, and the
regularization coefficient λ, one fused pass produces:

    e    = r − ⟨m̂_u, n̂_v⟩
    g_m  = e · n̂_v − λ · m̂_u     (ascent direction for m_u)
    g_n  = e · m̂_u − λ · n̂_v     (ascent direction for n_v)

so the Layer-2 update is φ' = γφ + η·g_m ; m' = m + φ' (and symmetrically
for n). Fusing error + both gradients means each operand tile is read from
VMEM once and all three outputs are produced in the same grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .predict import DEFAULT_TILE_B, _tile


def _nag_kernel(lam_ref, mu_ref, nv_ref, r_ref, e_ref, gm_ref, gn_ref):
    mu = mu_ref[...]
    nv = nv_ref[...]
    lam = lam_ref[0]
    e = r_ref[...] - jnp.sum(mu * nv, axis=-1)
    e_ref[...] = e
    gm_ref[...] = e[:, None] * nv - lam * mu
    gn_ref[...] = e[:, None] * mu - lam * nv


@functools.partial(jax.jit, static_argnames=("tile_b",))
def nag_gradients(mu_hat, nv_hat, r, lam, *, tile_b: int = DEFAULT_TILE_B):
    """Fused error + regularized gradient pair at the look-ahead point.

    Args:
      mu_hat: f32[B, D] look-ahead user rows  (m_u + γφ_u).
      nv_hat: f32[B, D] look-ahead item rows  (n_v + γψ_v).
      r:      f32[B] observed ratings.
      lam:    f32[] or f32[1] L2 regularization coefficient λ.
      tile_b: batch tile size.

    Returns:
      (e, g_m, g_n): f32[B], f32[B, D], f32[B, D].
    """
    b, d = mu_hat.shape
    tb = _tile(b, tile_b)
    grid = (b // tb,)
    lam_arr = jnp.asarray(lam, dtype=mu_hat.dtype).reshape((1,))
    return pl.pallas_call(
        _nag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # λ broadcast to every tile
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), mu_hat.dtype),
            jax.ShapeDtypeStruct((b, d), mu_hat.dtype),
            jax.ShapeDtypeStruct((b, d), mu_hat.dtype),
        ],
        interpret=True,
    )(lam_arr, mu_hat, nv_hat, r)

"""Layer-1 Pallas kernels for the A2PSGD LR model.

Each kernel has a pure-jnp oracle in `ref.py`; pytest + hypothesis pin the
kernels to the oracles. All kernels run with ``interpret=True`` — the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode is both the
correctness path and the CPU execution path. TPU performance is estimated
analytically in DESIGN.md §8.
"""

from .predict import predict_error, rowwise_dot
from .nag import nag_gradients
from .recommend import score_all_items

__all__ = ["predict_error", "rowwise_dot", "nag_gradients", "score_all_items"]

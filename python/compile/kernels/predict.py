"""Fused rowwise-dot / prediction-error Pallas kernel.

The LR model predicts r̂_uv = ⟨m_u, n_v⟩. Given a batch of gathered factor
rows mu[B,D], nv[B,D] (and optionally ratings r[B]) this kernel computes the
rowwise inner product and the prediction error e = r − ⟨m_u, n_v⟩ in a single
pass over the operands.

TPU mapping (see DESIGN.md §6 Hardware-Adaptation): the batch dimension is
tiled into (TB, D) VMEM blocks; the D-reduction stays inside a tile so each
operand streams HBM→VMEM exactly once. The kernel is elementwise+reduce
(VPU work, arithmetic intensity ≈ 0.5 FLOP/byte) — memory-bound by design,
so block shape targets streaming bandwidth, not the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile. 512 rows × 64 dims × 4 B = 128 KiB per operand tile —
# three operands resident ≈ 384 KiB, comfortably inside a TPU core's ~16 MiB
# VMEM with room for double buffering.
DEFAULT_TILE_B = 512


def _dot_kernel(mu_ref, nv_ref, out_ref):
    """out[b] = Σ_d mu[b,d] · nv[b,d] for one (TB, D) tile."""
    out_ref[...] = jnp.sum(mu_ref[...] * nv_ref[...], axis=-1)


def _error_kernel(mu_ref, nv_ref, r_ref, out_ref):
    """out[b] = r[b] − Σ_d mu[b,d] · nv[b,d] for one (TB, D) tile."""
    out_ref[...] = r_ref[...] - jnp.sum(mu_ref[...] * nv_ref[...], axis=-1)


def _tile(batch: int, tile_b: int) -> int:
    """Largest tile ≤ tile_b that divides batch (batch is padded upstream)."""
    t = min(tile_b, batch)
    while batch % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile_b",))
def rowwise_dot(mu, nv, *, tile_b: int = DEFAULT_TILE_B):
    """Batched prediction r̂[b] = ⟨mu[b,:], nv[b,:]⟩ via a Pallas kernel.

    Args:
      mu: f32[B, D] gathered user-factor rows.
      nv: f32[B, D] gathered item-factor rows.
      tile_b: batch tile size (rows per VMEM block).

    Returns:
      f32[B] rowwise inner products.
    """
    b, _ = mu.shape
    tb = _tile(b, tile_b)
    grid = (b // tb,)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, mu.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((tb, nv.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), mu.dtype),
        interpret=True,
    )(mu, nv)


@functools.partial(jax.jit, static_argnames=("tile_b",))
def predict_error(mu, nv, r, *, tile_b: int = DEFAULT_TILE_B):
    """Batched prediction error e[b] = r[b] − ⟨mu[b,:], nv[b,:]⟩.

    Args:
      mu: f32[B, D] gathered user-factor rows.
      nv: f32[B, D] gathered item-factor rows.
      r:  f32[B] observed ratings.
      tile_b: batch tile size.

    Returns:
      f32[B] prediction errors.
    """
    b, d = mu.shape
    tb = _tile(b, tile_b)
    grid = (b // tb,)
    return pl.pallas_call(
        _error_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), mu.dtype),
        interpret=True,
    )(mu, nv, r)

"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every function here is the direct transcription of the paper's math with no
tiling, fusion, or other kernel tricks. pytest (python/tests/) asserts the
Pallas kernels match these to float32 tolerance across hypothesis-generated
shapes and values.
"""

import jax.numpy as jnp


def rowwise_dot(mu, nv):
    """r̂[b] = ⟨mu[b,:], nv[b,:]⟩."""
    return jnp.sum(mu * nv, axis=-1)


def predict_error(mu, nv, r):
    """e[b] = r[b] − ⟨mu[b,:], nv[b,:]⟩."""
    return r - rowwise_dot(mu, nv)


def score_all_items(mu, n):
    """scores[v] = ⟨mu, n_v⟩ for one user row against the item matrix."""
    return n @ mu


def nag_gradients(mu_hat, nv_hat, r, lam):
    """(e, g_m, g_n) at the look-ahead point — paper Eqs. 4–5 inner term."""
    e = predict_error(mu_hat, nv_hat, r)
    g_m = e[:, None] * nv_hat - lam * mu_hat
    g_n = e[:, None] * mu_hat - lam * nv_hat
    return e, g_m, g_n


def sgd_step(mu, nv, r, eta, lam):
    """Plain SGD update (paper Eq. 3) for one batch of independent instances."""
    e = predict_error(mu, nv, r)
    mu2 = mu + eta * (e[:, None] * nv - lam * mu)
    nv2 = nv + eta * (e[:, None] * mu - lam * nv)
    return mu2, nv2


def nag_step(mu, nv, phi, psi, r, eta, lam, gamma):
    """Full NAG update (paper Eqs. 4–5) for one batch of independent instances."""
    mu_hat = mu + gamma * phi
    nv_hat = nv + gamma * psi
    e, g_m, g_n = nag_gradients(mu_hat, nv_hat, r, lam)
    phi2 = gamma * phi + eta * g_m
    psi2 = gamma * psi + eta * g_n
    return mu + phi2, nv + psi2, phi2, psi2

//! End-to-end serving demo: train on a synthetic twin, then serve batched
//! point predictions through the AOT XLA `predict` artifact via the
//! router/batcher service — Python never runs. Reports latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving
//! ```

use a2psgd::coordinator::service::PredictionService;
use a2psgd::prelude::*;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    // 1. Train.
    let data = data::synthetic::small(1234);
    println!("dataset: {}", data.describe());
    let cfg = TrainConfig::preset(EngineKind::A2psgd, &data).threads(4).epochs(20);
    let report = engine::train(&data, &cfg)?;
    println!("trained: best RMSE {:.4}", report.best_rmse());

    // 2. Start the prediction service over the trained factors.
    let svc = PredictionService::start(
        a2psgd::runtime::default_artifacts_dir(),
        report.factors,
        (data.rating_min, data.rating_max),
        Duration::from_millis(2),
    )?;

    // 3. Closed-loop latency probe (single in-flight request).
    let client = svc.client();
    let mut lat = Vec::new();
    for i in 0..200u32 {
        let t = Instant::now();
        let _ = client.predict(i % data.nrows(), i % data.ncols())?;
        lat.push(t.elapsed().as_secs_f64());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "closed-loop latency: p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
        lat[lat.len() / 2] * 1e3,
        lat[lat.len() * 95 / 100] * 1e3,
        lat[lat.len() * 99 / 100] * 1e3,
    );

    // 4. Open-loop throughput: many concurrent clients flood the batcher.
    let n_clients = 8;
    let per_client = 5_000usize;
    let t = Instant::now();
    std::thread::scope(|scope| {
        for tid in 0..n_clients {
            let c = svc.client();
            let nrows = data.nrows();
            let ncols = data.ncols();
            scope.spawn(move || {
                let mut rng = Rng::new(tid as u64);
                let pairs: Vec<(u32, u32)> = (0..per_client)
                    .map(|_| {
                        (
                            rng.gen_index(nrows as usize) as u32,
                            rng.gen_index(ncols as usize) as u32,
                        )
                    })
                    .collect();
                c.predict_many(&pairs).expect("predictions failed");
            });
        }
    });
    let secs = t.elapsed().as_secs_f64();
    let total = n_clients * per_client;
    drop(client);
    let stats = svc.shutdown();
    println!(
        "open-loop: {total} predictions in {secs:.3}s = {:.0} req/s \
         ({} PJRT batches, mean occupancy {:.1})",
        total as f64 / secs,
        stats.batches,
        stats.mean_batch()
    );
    Ok(())
}

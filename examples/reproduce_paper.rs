//! End-to-end reproduction driver: regenerates the paper's Tables III & IV
//! and the Fig. 3/4 convergence series on the two dataset twins, with all
//! five engines at the hardware thread count, multi-seed.
//!
//! ```bash
//! cargo run --release --example reproduce_paper             # full (ml1m+epinions twins)
//! A2PSGD_SCALE=small cargo run --release --example reproduce_paper   # quick smoke
//! ```
//!
//! Results land in `results/` and are summarized on stdout; EXPERIMENTS.md
//! records a pinned run.

use a2psgd::coordinator::{self, format_accuracy_table, format_time_table};
use a2psgd::prelude::*;

fn main() -> Result<()> {
    let scale = std::env::var("A2PSGD_SCALE").unwrap_or_else(|_| "paper".into());
    let (datasets, seeds, epochs, threads): (&[&str], Vec<u64>, u32, usize) = match scale.as_str()
    {
        "small" => (&["small"], vec![1, 2], 12, 4),
        "medium" => (&["medium"], vec![1, 2, 3], 30, 8),
        // The paper's setting: 32 threads (oversubscribed on small boxes —
        // the schedulers' contention behaviour is what matters).
        _ => (&["ml1m", "epinions"], vec![1, 2, 3], 45, 32),
    };
    println!(
        "reproduce_paper: scale={scale} threads={threads} seeds={}",
        seeds.len()
    );

    for key in datasets {
        let probe = coordinator::resolve_dataset(key, seeds[0])?;
        println!("\n=== {} ===", probe.describe());
        let mk = move |engine: EngineKind, data: &Dataset| {
            TrainConfig::preset(engine, data).threads(threads).epochs(epochs)
        };
        let mut cells = Vec::new();
        for eng in EngineKind::paper_set() {
            eprint!("  {:<9} ", eng.to_string());
            let t = std::time::Instant::now();
            let cell = coordinator::run_cell(key, eng, &seeds, &mk)?;
            eprintln!(
                "best RMSE {}  RMSE-time {}  ({:.1}s wall)",
                cell.rmse.fmt_paper(4),
                cell.rmse_time.fmt_paper(2),
                t.elapsed().as_secs_f64()
            );
            cells.push(cell);
        }
        // Table III / Table IV rows for this dataset.
        println!("\n{}", format_accuracy_table(key, &cells));
        println!("{}", format_time_table(key, &cells));
        // Fig. 3 / Fig. 4 series.
        let out = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
        coordinator::write_convergence_csv(&out, key, &cells)?;
        println!("convergence series → results/convergence_{key}_*.csv");

        // Paper-shape checks (who wins), reported not asserted.
        let a2 = cells
            .iter()
            .find(|c| c.engine == EngineKind::A2psgd)
            .expect("paper set includes A2PSGD");
        let best_other_rmse = cells
            .iter()
            .filter(|c| c.engine != EngineKind::A2psgd)
            .map(|c| c.rmse.mean)
            .fold(f64::INFINITY, f64::min);
        println!(
            "shape check: A2PSGD RMSE {:.4} vs best baseline {:.4} → {}",
            a2.rmse.mean,
            best_other_rmse,
            if a2.rmse.mean <= best_other_rmse {
                "A2PSGD wins (paper shape holds)"
            } else {
                "baseline wins (deviation)"
            }
        );
    }
    Ok(())
}

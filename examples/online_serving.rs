//! End-to-end online learning demo: warm-train a model, serve it, stream
//! interactions from users the model has *never seen*, and watch the same
//! running service pick up refreshed factors with zero downtime.
//!
//! ```bash
//! cargo run --release --example online_serving
//! # or without the XLA toolchain:
//! cargo run --release --no-default-features --example online_serving
//! ```
//!
//! The demo asserts its own acceptance criteria:
//! 1. the service answers a prediction for a user that did not exist at
//!    initial training time,
//! 2. rolling holdout RMSE after streaming is strictly lower than under the
//!    warm snapshot, and
//! 3. the snapshot version counter proves the factors were hot-swapped into
//!    the *same* service instance (zero restarts).

use a2psgd::coordinator::service::{BackendMode, ExclusionSet, PredictionService};
use a2psgd::prelude::*;
use a2psgd::stream::{EventSource, OnlineTrainer};
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    // 1. A dataset whose last 25% of users are withheld from training and
    //    replayed as a live interaction stream.
    let data = data::synthetic::small(1234);
    println!("dataset: {}", data.describe());
    let mut split = a2psgd::stream::replay_split(&data, 0.75, 7);
    println!(
        "warm split: {} warm users, {} cold users, {} events to stream",
        split.warm.nrows(),
        split.n_cold_users,
        split.stream.remaining()
    );

    // 2. Warm offline training (the paper's A²PSGD engine).
    let cfg = TrainConfig::preset(EngineKind::A2psgd, &split.warm).threads(4).epochs(15);
    let report = engine::train(&split.warm, &cfg)?;
    println!("warm training: best RMSE {:.4}", report.best_rmse());

    // 3. Serve through a hot-swappable snapshot store. Auto backend: XLA
    //    artifacts when available, native dot products otherwise.
    let store = Arc::new(SnapshotStore::new(report.factors.clone()));
    let exclusions = Arc::new(ExclusionSet::from_matrix(&split.warm.train));
    let svc = PredictionService::start_over_store(
        a2psgd::runtime::default_artifacts_dir(),
        Arc::clone(&store),
        (data.rating_min, data.rating_max),
        Duration::from_millis(2),
        Some(Arc::clone(&exclusions)),
        BackendMode::Auto,
    )?;
    let client = svc.client();
    let initial = store.load();
    assert_eq!(initial.version(), 1);

    // A cold user the warm model knows nothing about.
    let cold = *data
        .train
        .entries()
        .iter()
        .chain(data.test.entries())
        .find(|e| e.u >= split.warm.nrows())
        .expect("synthetic small always has cold-user interactions");
    let unknown_dense = initial.factors().nrows(); // not a valid row yet
    let before_pred = client.predict(unknown_dense, cold.v)?;
    let midpoint = 0.5 * (data.rating_min + data.rating_max);
    assert!(
        (before_pred - midpoint).abs() < 1e-6,
        "unknown user must answer the midpoint prior, got {before_pred}"
    );
    println!("before: r̂(cold user {}, item {}) = {before_pred:.3} (unknown → midpoint)", cold.u, cold.v);

    // 4. Stream every cold interaction through the online trainer while the
    //    service keeps answering.
    let scfg = StreamConfig::preset(&data.name).threads(4).seed(7);
    let mut trainer = OnlineTrainer::new(
        report.factors,
        split.map,
        scfg,
        Arc::clone(&store),
        (data.rating_min, data.rating_max),
    )?;
    trainer.share_exclusions(Arc::clone(&exclusions));
    let mut served_mid_stream = 0u32;
    while let Some(batch) = split.stream.next_batch(scfg.batch) {
        trainer.ingest(&batch);
        // Interleave live queries to prove the service never stops.
        let _ = client.predict(0, 0)?;
        served_mid_stream += 1;
    }
    trainer.publish();
    let stats = *trainer.stats();
    println!(
        "streamed {} events in {} batches: {} new users, {} new items, {} window updates",
        stats.events, stats.batches, stats.new_users, stats.new_items, stats.updates
    );

    // 5. Acceptance checks.
    // (a) The same service now answers the cold user from live factors.
    let du = trainer.map().user(cold.u as u64).expect("cold user folded in");
    let dv = trainer.map().item(cold.v as u64).expect("item known");
    assert!(du >= initial.factors().nrows(), "cold user postdates warm training");
    let after_pred = client.predict(du, dv)?;
    println!(
        "after:  r̂(cold user {}, item {}) = {after_pred:.3} (observed r = {})",
        cold.u, cold.v, cold.r
    );

    // (b) Rolling holdout RMSE strictly improves over the warm snapshot.
    let before_rmse = trainer
        .holdout()
        .rmse(initial.factors(), data.rating_min, data.rating_max)
        .expect("holdout ring is non-empty");
    let after_rmse = trainer.holdout_rmse().expect("holdout ring is non-empty");
    println!("rolling holdout RMSE: {before_rmse:.4} (warm snapshot) → {after_rmse:.4} (live)");
    assert!(
        after_rmse < before_rmse,
        "streaming must improve rolling RMSE: {before_rmse:.4} → {after_rmse:.4}"
    );

    // (c) Zero restarts, verified via the snapshot version counter: one
    //     service instance observed both the warm and the live generations.
    drop(client);
    let sstats = svc.shutdown();
    println!(
        "hot swap: store at v{}, service observed {} versions (last v{}), {} mid-stream probes",
        store.version(),
        sstats.versions_seen,
        sstats.last_version,
        served_mid_stream
    );
    assert!(store.version() > 1, "snapshots must have been published");
    assert!(sstats.versions_seen >= 2, "service must have served ≥ 2 factor generations");
    assert_eq!(sstats.last_version, store.version(), "service ends on the latest snapshot");
    println!("online serving demo: all acceptance checks passed ✔");
    Ok(())
}

//! Thread-scaling study (the paper's §III-A motivation): updates/second and
//! accuracy of FPSGD (global-lock scheduler) vs A²PSGD (lock-free) as the
//! thread count grows. This is where the global lock's queueing shows.
//!
//! ```bash
//! cargo run --release --example scaling_threads
//! ```

use a2psgd::bench_harness::Table;
use a2psgd::prelude::*;

fn main() -> Result<()> {
    let data = data::synthetic::medium(7);
    println!("dataset: {}\n", data.describe());
    let max = engine::default_threads();
    let mut counts = vec![1usize, 2, 4, 8];
    counts.retain(|&c| c <= max);
    if !counts.contains(&max) {
        counts.push(max);
    }

    let mut table = Table::new(&[
        "threads",
        "FPSGD Mups",
        "A2PSGD Mups",
        "speedup",
        "FPSGD rmse",
        "A2PSGD rmse",
    ]);
    let mut csv = String::from("threads,fpsgd_mups,a2psgd_mups,fpsgd_rmse,a2psgd_rmse\n");
    for &c in &counts {
        let run = |kind: EngineKind| -> Result<(f64, f64)> {
            let cfg = TrainConfig::preset(kind, &data)
                .threads(c)
                .epochs(10)
                .no_early_stop();
            let r = engine::train(&data, &cfg)?;
            Ok((r.updates_per_sec() / 1e6, r.best_rmse()))
        };
        let (fp_ups, fp_rmse) = run(EngineKind::Fpsgd)?;
        let (a2_ups, a2_rmse) = run(EngineKind::A2psgd)?;
        table.row(&[
            c.to_string(),
            format!("{fp_ups:.2}"),
            format!("{a2_ups:.2}"),
            format!("{:.2}x", a2_ups / fp_ups),
            format!("{fp_rmse:.4}"),
            format!("{a2_rmse:.4}"),
        ]);
        csv.push_str(&format!("{c},{fp_ups},{a2_ups},{fp_rmse},{a2_rmse}\n"));
    }
    println!("{}", table.render());
    let path = a2psgd::bench_harness::write_results_csv("scaling_threads.csv", &csv)?;
    println!("series → {}", path.display());
    Ok(())
}

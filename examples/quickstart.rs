//! Quickstart: train the A²PSGD LR model on a small synthetic HDS matrix and
//! compare against the serial reference.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use a2psgd::prelude::*;

fn main() -> Result<()> {
    // 1. A small synthetic HDS dataset (400×300, ~12K instances, Zipf skew).
    let data = data::synthetic::small(42);
    println!("dataset: {}", data.describe());

    // 2. Train with the paper's engine: lock-free scheduler + balanced
    //    blocking + Nesterov momentum.
    let cfg = TrainConfig::preset(EngineKind::A2psgd, &data)
        .threads(4)
        .epochs(25)
        .dim(16);
    let report = engine::train(&data, &cfg)?;

    println!("\nA2PSGD convergence:");
    for p in report.history.points().iter().step_by(4) {
        println!(
            "  epoch {:>2}: RMSE {:.4}  MAE {:.4}  ({:.3}s)",
            p.epoch, p.rmse, p.mae, p.train_seconds
        );
    }
    println!(
        "best RMSE {:.4} in {:.3}s  ({:.2}M updates/s)",
        report.best_rmse(),
        report.rmse_time(),
        report.updates_per_sec() / 1e6
    );

    // 3. Sanity: the serial reference reaches a similar optimum.
    let seq = engine::train(&data, &TrainConfig::preset(EngineKind::Seq, &data).epochs(25))?;
    println!("serial reference best RMSE {:.4}", seq.best_rmse());

    // 4. Point predictions from the trained factors.
    let f = &report.factors;
    for (u, v) in [(0u32, 0u32), (5, 10), (100, 200)] {
        println!(
            "r̂({u},{v}) = {:.2}",
            f.predict_clamped(u, v, data.rating_min, data.rating_max)
        );
    }
    Ok(())
}

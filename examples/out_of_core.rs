//! Out-of-core dataset pipeline, end to end:
//!
//! 1. synthesize the small twin and write it as a ratings text file;
//! 2. `pack` it into a `.a2ps` shard directory (binary shards split by row
//!    range, embedded id map, CRC per shard);
//! 3. train A²PSGD **out-of-core** — shards stream through bounded buffers
//!    straight into the block grid, no monolithic COO;
//! 4. train the same config on the in-memory text path and assert the two
//!    runs agree (bit-identical at threads=1).
//!
//! ```bash
//! cargo run --release --no-default-features --example out_of_core
//! ```

use a2psgd::data::shard::{pack_text, PackOptions};
use a2psgd::data::{loader, synthetic};
use a2psgd::engine::{train, train_ooc, EngineKind, TrainConfig};
use a2psgd::prelude::*;

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join("a2psgd_example_ooc");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir)?;

    // 1. A ratings text file (stand-in for MovieLens/Epinions dumps).
    let twin = synthetic::small(42);
    let text_path = dir.join("ratings.tsv");
    let mut text = String::new();
    for e in twin.train.entries().iter().chain(twin.test.entries()) {
        text.push_str(&format!("{} {} {}\n", e.u, e.v, e.r));
    }
    std::fs::write(&text_path, text)?;
    println!("wrote {} ({} instances)", text_path.display(), twin.total_nnz());

    // 2. Pack once. Tiny shard budget here so the demo visibly shards; real
    //    runs use the 64 MiB default (`--shard-mb` / `[data] shard_mb`).
    let shard_dir = dir.join("shards");
    let stats = pack_text(&text_path, &shard_dir, &PackOptions { shard_bytes: 16 << 10 })?;
    println!(
        "packed → {} shards, {} records, {}x{} matrix, {} duplicate(s) dropped",
        stats.shards, stats.nnz, stats.nrows, stats.ncols, stats.duplicates
    );

    // 3. Out-of-core training: the text file and the monolithic COO never
    //    exist in memory — shards feed the block grid through bounded
    //    buffers, decoded in parallel on the worker pool.
    let cfg = TrainConfig::preset_named(EngineKind::A2psgd, "ooc-demo")
        .threads(1)
        .epochs(5)
        .dim(8)
        .no_early_stop();
    let ooc = train_ooc(&shard_dir, "ooc-demo", &cfg, 0.3, cfg.seed, 4096)?;
    println!("out-of-core  A2PSGD: final RMSE {:.6}", ooc.final_rmse());

    // 4. The in-memory reference over the same records.
    let data = loader::load_file(&text_path, "ooc-demo", 0.3, cfg.seed)?;
    let mem = train(&data, &cfg)?;
    println!("in-memory    A2PSGD: final RMSE {:.6}", mem.final_rmse());

    let diff = (ooc.final_rmse() - mem.final_rmse()).abs();
    assert!(diff < 1e-6, "paths diverged by {diff}");
    println!("parity OK: |ΔRMSE| = {diff:.2e} (< 1e-6)");

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

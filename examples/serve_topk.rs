//! End-to-end serving-tier demo: pack-and-train a model, expose it over
//! the line-protocol TCP front end, query quantized top-k over the wire,
//! and hot-swap the model mid-traffic without dropping a single in-flight
//! request.
//!
//! ```bash
//! cargo run --release --example serve_topk
//! # or without the XLA toolchain:
//! cargo run --release --no-default-features --example serve_topk
//! ```
//!
//! The demo asserts its own acceptance criteria:
//! 1. `TOPK`, `PREDICT`, and `STATS` all answer over a real TCP socket
//!    (the wire grammar documented in SERVING.md),
//! 2. quantized (int8) top-k answers agree with the exact f32 ranking at
//!    recall@k ≥ 0.95 for the served users,
//! 3. snapshots published *while clients are mid-conversation* are picked
//!    up by the same server (versions_seen > 1) with **zero** dropped
//!    requests — every line sent gets exactly one reply line.

use a2psgd::coordinator::net::{NetOptions, TopKServer};
use a2psgd::coordinator::service::{PredictionService, ServiceOptions};
use a2psgd::metrics::topn::rank_items;
use a2psgd::prelude::*;
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

const K: usize = 10;

fn main() -> Result<()> {
    // 1. Train the paper's A²PSGD engine on the small synthetic dataset.
    let data = data::synthetic::small(4242);
    println!("dataset: {}", data.describe());
    let cfg = TrainConfig::preset(EngineKind::A2psgd, &data).threads(4).epochs(10);
    let report = engine::train(&data, &cfg)?;
    println!("warm model: best RMSE {:.4}", report.best_rmse());
    let factors = report.factors;

    // 2. Start the native service with the int8 quantized top-k index and
    //    put the TCP front end over it (port 0 = OS-assigned).
    let store = Arc::new(SnapshotStore::new(factors.clone()));
    let svc = PredictionService::start_with_options(
        std::path::PathBuf::new(),
        Arc::clone(&store),
        None,
        ServiceOptions::native(),
    )?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let server =
        TopKServer::start(listener, svc.client(), NetOptions { threads: 2, deadline: None })?;
    let addr = server.addr();
    println!("serving on {addr}");

    // 3. Clients converse over the wire while the publisher hot-swaps
    //    fresh factors between their requests. Every client counts one
    //    reply line per request line — any drop fails the assertion.
    let users: Vec<u32> = (0..factors.nrows().min(16)).collect();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let (sent, answered, swaps) = std::thread::scope(|s| {
        let publisher = s.spawn(|| {
            let mut swaps = 0u64;
            let mut g = factors.clone();
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                g.m[swaps as usize % g.m.len()] += 1e-4;
                store.publish(g.clone());
                swaps += 1;
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            swaps
        });
        let clients: Vec<_> = (0..3u32)
            .map(|c| {
                let users = &users;
                s.spawn(move || -> Result<(u64, u64)> {
                    let stream = TcpStream::connect(addr)?;
                    let mut reader = BufReader::new(stream.try_clone()?);
                    let mut w = stream;
                    let mut line = String::new();
                    let (mut sent, mut answered) = (0u64, 0u64);
                    for round in 0..40u32 {
                        let u = users[((c + round) as usize) % users.len()];
                        writeln!(w, "TOPK {u} {K}")?;
                        writeln!(w, "PREDICT {u} {}", (round % 50))?;
                        sent += 2;
                        for _ in 0..2 {
                            line.clear();
                            reader.read_line(&mut line)?;
                            anyhow::ensure!(
                                line.starts_with("OK "),
                                "expected OK, got {line:?}"
                            );
                            answered += 1;
                        }
                    }
                    writeln!(w, "QUIT")?;
                    Ok((sent, answered))
                })
            })
            .collect();
        let mut sent = 0u64;
        let mut answered = 0u64;
        for c in clients {
            let (s_, a_) = c.join().expect("client thread")?;
            sent += s_;
            answered += a_;
        }
        stop.store(true, std::sync::atomic::Ordering::Release);
        let swaps = publisher.join().expect("publisher thread");
        Ok::<_, anyhow::Error>((sent, answered, swaps))
    })?;
    println!("wire traffic: {answered}/{sent} requests answered across {swaps} hot-swaps");
    assert_eq!(answered, sent, "every request line must get exactly one reply line");

    // 4. STATS over the wire, then an orderly teardown: front end first
    //    (its workers hold service-client clones), service second. The
    //    folded stats prove the same server saw multiple model versions
    //    (hot-swap, not restart) and shed nothing at this load.
    let stats_line = one_shot(addr, "STATS")?;
    println!("STATS → {stats_line}");
    server.shutdown();
    let svc_stats = svc.shutdown();
    assert!(svc_stats.versions_seen > 1, "hot-swap never happened");
    assert_eq!(svc_stats.topk_shed, 0, "no admission shedding expected at this load");

    // 5. Quantized answers track the exact f32 ranking: recall@K against
    //    rank_items on the final published factors.
    let final_f = store.load();
    let empty = HashSet::new();
    let quant = a2psgd::model::QuantizedIndex::build(
        final_f.factors(),
        a2psgd::model::QuantMode::Int8,
    );
    let mut hits = 0usize;
    for &u in &users {
        let exact: HashSet<u32> = rank_items(final_f.factors(), u, &empty, K)
            .into_iter()
            .map(|(v, _)| v)
            .collect();
        hits += quant
            .top_k(final_f.factors().m_row(u), K, &empty)
            .iter()
            .filter(|(v, _)| exact.contains(v))
            .count();
    }
    let recall = hits as f64 / (users.len() * K) as f64;
    println!("int8 recall@{K} vs exact f32: {recall:.3}");
    assert!(recall >= 0.95, "quantized ranking diverged: recall {recall:.3}");

    println!("OK: wire serving, hot-swap mid-traffic, and quantized recall all hold");
    Ok(())
}

/// Open a fresh connection, send one line, read one reply line.
fn one_shot(addr: std::net::SocketAddr, req: &str) -> Result<String> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut w = stream;
    writeln!(w, "{req}")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim_end().to_string())
}
